"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.expr import distance_values, in_range, order_key
from repro.core.schema import Metric
from repro.core.sql import parse_sql
from repro.core.plan import Filter, walk_plan
from repro.index.flat import masked_topk
from repro.training.step import dequantize_int8, quantize_int8

FLOATS = st.floats(-1e3, 1e3, allow_nan=False, width=32)


@settings(max_examples=40, deadline=None)
@given(st.lists(FLOATS, min_size=1, max_size=64), st.data())
def test_masked_topk_invariants(keys, data):
    n = len(keys)
    mask = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    k = data.draw(st.integers(1, n))
    keys_a = jnp.asarray(np.array(keys, np.float32))
    ids = jnp.arange(n, dtype=jnp.int32)
    mk, mi, mv = masked_topk(keys_a, ids, jnp.asarray(mask), k)
    mk, mi, mv = np.asarray(mk), np.asarray(mi), np.asarray(mv)
    masked_keys = np.array(keys, np.float32)[np.asarray(mask)]
    # 1) number of valid results = min(k, #masked)
    assert mv.sum() == min(k, len(masked_keys))
    # 2) valid ids are distinct and satisfy the mask
    got = mi[mv]
    assert len(set(got.tolist())) == len(got)
    assert all(mask[i] for i in got)
    # 3) ascending order and exactly the smallest masked keys
    assert (np.diff(mk[mv]) >= 0).all()
    want = np.sort(masked_keys)[:mv.sum()]
    np.testing.assert_allclose(np.sort(mk[mv]), want, rtol=1e-6, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(list(Metric)),
       st.lists(st.lists(FLOATS, min_size=4, max_size=4), min_size=1,
                max_size=32),
       st.lists(FLOATS, min_size=4, max_size=4), FLOATS)
def test_range_consistent_with_order_key(metric, xs, q, radius):
    """in_range(v, r) must equal order_key(v) <= order_key(r): the index's
    key-space reasoning and the predicate semantics cannot diverge."""
    x = jnp.asarray(np.array(xs, np.float32))
    qv = jnp.asarray(np.array(q, np.float32))
    raw = distance_values(metric, x, qv)
    lhs = np.asarray(in_range(metric, raw, radius))
    rhs = np.asarray(order_key(metric, raw)
                     <= order_key(metric, jnp.float32(radius)))
    assert (lhs == rhs).all()


@settings(max_examples=40, deadline=None)
@given(st.lists(FLOATS, min_size=1, max_size=256))
def test_int8_error_feedback_bound(vals):
    """Quantization error is bounded by scale/2 per element — the invariant
    the error-feedback compressor relies on."""
    x = jnp.asarray(np.array(vals, np.float32))
    q, scale = quantize_int8(x)
    err = np.asarray(x - dequantize_int8(q, scale))
    assert (np.abs(err) <= float(scale) * 0.5 + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 100), st.booleans())
def test_sql_roundtrip_predicates(thresh, limit, flip):
    op = "<" if flip else ">"
    sql = (f"SELECT sample_id FROM products WHERE price {op} {thresh} "
           f"ORDER BY DISTANCE(embedding, ${{qv}}) LIMIT {limit}")
    plan = parse_sql(sql)
    filt = next(n for n in walk_plan(plan) if isinstance(n, Filter))
    assert filt.predicate.op == op
    assert filt.predicate.rhs.value == thresh


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4))
def test_ivf_exactness_property(nlist, k):
    """IVF with 'bound' termination + unlimited probes is EXACT for any
    clustered corpus — the core soundness property of the adaptation."""
    rng = np.random.default_rng(nlist * 13 + k)
    x = rng.standard_normal((300, 8)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    from repro.index import FlatIndex, build_ivf
    from repro.index.ivf import ProbeConfig, ivf_topk
    corpus = jnp.asarray(x)
    idx = build_ivf(jax.random.key(0), corpus, nlist=nlist,
                    metric=Metric.L2, iters=3)
    flat = FlatIndex(Metric.L2, corpus)
    q = corpus[0] + 0.05
    gt, _, _ = flat.topk(q, k)
    ids, _, valid, _ = ivf_topk(
        idx, corpus, q, k,
        cfg=ProbeConfig(max_probes=nlist, termination="bound"))
    assert set(np.asarray(ids).tolist()) == set(np.asarray(gt).tolist())
