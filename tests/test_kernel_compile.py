"""Mosaic compile-check of the batched scan kernels on a real TPU.

The tier-1 suite sweeps the fp32 and quantized batch kernels in Pallas
interpret mode (``kernels.default_interpret()`` flips automatically off
accelerator-less hosts), which validates semantics but NOT that Mosaic
accepts the kernels' (k, BLOCK_Q) output layout and the column-parallel
extract-min — the ROADMAP "Mosaic validation on real TPU" item.  These
``slow``-marked tests force ``interpret=False`` and drive the wrappers
through ``jax.jit(...).lower(...).compile()`` on an attached TPU backend:

* the fp32 and quantized (int8 / bf16) top-k kernels compile and emit the
  (Q, k) contract shapes, fp32 sims match a NumPy reference, and the
  quantized outputs stay BIT-identical to the compiled fp32 outputs —
  the same-shape-replay invariant must survive real MXU accumulation;
* the fp32 and quantized range kernels compile and agree the same way
  (ids / sims / valid / count);
* the SINGLE-query fused kernels (matvec-shaped pipelines with their own
  output layout) compile and match NumPy;
* the column-parallel extract-min compiles across a k sweep (every k is a
  distinct (k, BLOCK_Q) layout Mosaic must accept).

Without a TPU backend every test skips cleanly (interpret-mode coverage
already runs in the tier-1 suite — tests/test_quant.py and the kernel
tests); run via ``SMOKE_SLOW=1 bash scripts/smoke.sh`` on TPU hosts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Metric
from repro.data.quantized import quantize_corpus
from repro.kernels.ops import (fused_range_scan, fused_range_topk_batch,
                               fused_scan_topk, fused_scan_topk_batch)
from repro.kernels.quant import (fused_range_topk_batch_q,
                                 fused_scan_topk_batch_q)

# slow-marked AND backend-gated at module level: off-TPU runs show the
# explicit skip reason in the `-ra` summary instead of silently passing by
pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(
        jax.default_backend() != "tpu",
        reason="no TPU backend attached (default_backend="
               f"{jax.default_backend()!r}): Mosaic compile-check needs "
               "real hardware; interpret-mode coverage runs in tier-1"),
]

N, D, QN, K, CAP = 4096, 128, 128, 8, 16


def _require_tpu():
    backend = jax.default_backend()
    if backend != "tpu":
        pytest.skip(f"no TPU backend attached (default_backend="
                    f"{backend!r}): Mosaic compile-check needs real "
                    f"hardware; interpret-mode coverage runs in tier-1")


def _data():
    rng = np.random.default_rng(0)
    corpus = rng.standard_normal((N, D)).astype(np.float32)
    corpus /= np.linalg.norm(corpus, axis=1, keepdims=True)
    queries = rng.standard_normal((QN, D)).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    return corpus, queries


def _tree_equal(a, b, ctx):
    for i, (x, y) in enumerate(zip(a, b)):
        assert np.array_equal(np.asarray(x), np.asarray(y)), f"{ctx}[{i}]"


@pytest.mark.parametrize("mode", ["int8", "bf16"])
def test_topk_kernels_compile_and_agree(mode):
    _require_tpu()
    corpus, queries = _data()
    metric = Metric.INNER_PRODUCT

    f32 = jax.jit(lambda c, q: fused_scan_topk_batch(
        c, q, K, None, metric, interpret=False))
    ref = f32.lower(corpus, queries).compile()(corpus, queries)
    ids, sims, valid = (np.asarray(x) for x in ref)
    assert ids.shape == sims.shape == valid.shape == (QN, K)
    assert valid.all()
    # fp32 sims against the NumPy top-k values: the compiled kernel's
    # (k, BLOCK_Q) extraction must not drop or reorder real winners
    want = np.sort(corpus @ queries.T, axis=0)[-K:][::-1].T
    np.testing.assert_allclose(np.sort(sims, axis=1)[:, ::-1], want,
                               rtol=1e-5, atol=1e-5)

    qc = quantize_corpus(corpus, mode)
    qk = jax.jit(lambda c, z, s, q: fused_scan_topk_batch_q(
        c, z, s, q, K, None, metric, interpret=False))
    got = qk.lower(corpus, qc.qvecs, qc.scales, queries).compile()(
        corpus, jnp.asarray(qc.qvecs), jnp.asarray(qc.scales), queries)
    _tree_equal(ref, got, ctx=f"topk/{mode}")


@pytest.mark.parametrize("mode", ["int8", "bf16"])
def test_range_kernels_compile_and_agree(mode):
    _require_tpu()
    corpus, queries = _data()
    metric = Metric.INNER_PRODUCT
    radius = np.float32(0.2)

    f32 = jax.jit(lambda c, q: fused_range_topk_batch(
        c, q, radius, None, metric, CAP, interpret=False))
    ref = f32.lower(corpus, queries).compile()(corpus, queries)
    assert np.asarray(ref[0]).shape == (QN, CAP)
    assert np.asarray(ref[3]).shape == (QN,)

    qc = quantize_corpus(corpus, mode)
    qk = jax.jit(lambda c, z, s, h, l1, l2, q: fused_range_topk_batch_q(
        c, z, s, h, l1, l2, q, radius, None, metric, CAP, interpret=False))
    args = (corpus, jnp.asarray(qc.qvecs), jnp.asarray(qc.scales),
            jnp.asarray(qc.half_step), jnp.asarray(qc.row_l1),
            jnp.asarray(qc.row_l2), queries)
    got = qk.lower(*args).compile()(*args)
    _tree_equal(ref, got, ctx=f"range/{mode}")


def test_single_query_kernels_compile_and_agree():
    """The single-query fused kernels — a matvec-shaped (BLOCK_N, D)·(D,)
    pipeline with a different output layout from the batch kernels — must
    also pass Mosaic (the ROADMAP item called them out as interpret-only)."""
    _require_tpu()
    corpus, queries = _data()
    metric = Metric.INNER_PRODUCT
    query = queries[0]

    topk = jax.jit(lambda c, q: fused_scan_topk(
        c, q, K, None, metric, interpret=False))
    ids, sims, valid = (np.asarray(x)
                        for x in topk.lower(corpus, query).compile()(
                            corpus, query))
    assert ids.shape == sims.shape == valid.shape == (K,)
    assert valid.all()
    want_ids = np.argsort(corpus @ query)[-K:][::-1]
    assert set(ids) == set(want_ids)
    np.testing.assert_allclose(np.sort(sims), np.sort(corpus @ query)[-K:],
                               rtol=1e-5, atol=1e-5)

    radius = np.float32(0.2)
    rng_scan = jax.jit(lambda c, q: fused_range_scan(
        c, q, radius, None, metric, interpret=False))
    hit, raw, count = (np.asarray(x)
                       for x in rng_scan.lower(corpus, query).compile()(
                           corpus, query))
    want_hit = (corpus @ query) >= radius
    assert np.array_equal(hit, want_hit)
    assert int(count) == int(want_hit.sum())
    np.testing.assert_allclose(raw[hit], (corpus @ query)[hit],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k", [1, 4, 16, 64])
def test_extract_min_sweep_compiles(k):
    """Sweep the column-parallel extract-min over k: every k changes the
    (k, BLOCK_Q) output layout and the in-register k-step loop Mosaic must
    accept — the batch tests above only exercise k=8."""
    _require_tpu()
    corpus, queries = _data()
    metric = Metric.INNER_PRODUCT
    fn = jax.jit(lambda c, q: fused_scan_topk_batch(
        c, q, k, None, metric, interpret=False))
    ids, sims, valid = (np.asarray(x)
                        for x in fn.lower(corpus, queries).compile()(
                            corpus, queries))
    assert ids.shape == sims.shape == valid.shape == (QN, k)
    assert valid.all()
    want = np.sort(corpus @ queries.T, axis=0)[-k:][::-1].T
    np.testing.assert_allclose(np.sort(sims, axis=1)[:, ::-1], want,
                               rtol=1e-5, atol=1e-5)
