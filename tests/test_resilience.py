"""Resilient serving tier (DESIGN.md §11): admission control, graceful
degradation, deterministic fault injection, and the asyncio front door.

Contracts under test:
* admission is a pure function of observed depth — reject at the watermark
  with a ``retry_after_ms`` that scales with how far over demand pushes;
* poisoned (non-finite) binds are rejected at the door, never batched;
* the :class:`LoadController` steps UP immediately to the deepest reached
  watermark and DOWN one level at a time behind hysteresis;
* fault injection replays bit-identically from a seed, with per-fault-type
  streams that do not shift each other;
* a degraded :class:`ResilientScheduler` execution reports its level and
  probe budget through ``Result.explain()``;
* :class:`QueryServer` resolves EVERY submit to a typed outcome — result,
  BackpressureError, DeadlineExceededError — never a hang.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.api import connect
from repro.core import Metric
from repro.data import make_laion_catalog
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig
from repro.launch.serve import QueryServer, ServeConfig
from repro.serving import (AdmissionConfig, AdmissionController,
                           BackpressureError, DeadlineExceededError,
                           DegradePolicy, FaultInjector, FaultSpec,
                           InjectedKernelError, LoadController,
                           PoisonedBindError, ResilientScheduler,
                           SchedulerConfig, validate_binds)

SQL = ("SELECT sample_id FROM products WHERE price < ${p} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")


@pytest.fixture(scope="module")
def env():
    cat = make_laion_catalog(n_rows=600, n_queries=8, dim=16, n_modes=8,
                             seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=8,
                    metric=Metric.INNER_PRODUCT, iters=2)
    cat.register_index("products", "embedding", idx)
    db = connect(cat, engine="chase",
                 probe=ProbeConfig(max_probes=8, probe_batch=2,
                                   termination="counter"))
    stmt = db.prepare(SQL)
    qs = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    return cat, stmt, qs


def _binds(qs, i=0):
    return {"qv": qs[i % qs.shape[0]], "p": np.float32(1e9)}


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_rejects_at_watermark_with_scaled_retry_after():
    adm = AdmissionController(AdmissionConfig(max_queue_depth=4,
                                              retry_after_ms=10.0))
    for depth in range(4):
        adm.admit(depth)                    # below watermark: admitted
    with pytest.raises(BackpressureError) as ei:
        adm.admit(4)
    assert ei.value.retry_after_ms == pytest.approx(10.0)
    assert ei.value.watermark == 4
    with pytest.raises(BackpressureError) as ei:
        adm.admit(8)                        # 100% over: retry hint doubles
    assert ei.value.retry_after_ms == pytest.approx(20.0)
    assert adm.snapshot() == {"admitted": 4, "rejected": 2}


def test_admission_config_validation():
    with pytest.raises(ValueError, match="max_queue_depth"):
        AdmissionConfig(max_queue_depth=0)


def test_validate_binds_rejects_non_finite():
    validate_binds({"qv": np.ones(4, np.float32), "p": np.float32(2.0)})
    bad = np.ones(4, np.float32)
    bad[2] = np.nan
    with pytest.raises(PoisonedBindError, match="qv"):
        validate_binds({"qv": bad})
    with pytest.raises(PoisonedBindError, match="p"):
        validate_binds({"p": np.float32(np.inf)})
    validate_binds({"k": np.int32(7)})      # integers are never "poisoned"


# ---------------------------------------------------------------------------
# degradation policy + load controller
# ---------------------------------------------------------------------------

def test_degrade_policy_validation():
    DegradePolicy(steps=((4, 8), (8, 2)), hysteresis=2)     # well-formed
    with pytest.raises(ValueError, match="ascending"):
        DegradePolicy(steps=((8, 8), (4, 2)))
    with pytest.raises(ValueError, match="ascending"):
        DegradePolicy(steps=((4, 8), (4, 2)))               # duplicate depth
    with pytest.raises(ValueError, match="budgets must be >= 1"):
        DegradePolicy(steps=((4, 0),))
    with pytest.raises(ValueError, match="non-increasing"):
        DegradePolicy(steps=((4, 2), (8, 8)))               # effort UP? no
    with pytest.raises(ValueError, match="hysteresis"):
        DegradePolicy(hysteresis=-1)


def test_load_controller_up_immediate_down_hysteretic():
    lc = LoadController(DegradePolicy(steps=((4, 8), (8, 2)), hysteresis=2))
    assert lc.observe(0) == 0 and lc.probe_budget() is None
    assert lc.observe(4) == 1 and lc.probe_budget() == 8
    assert lc.observe(9) == 2 and lc.probe_budget() == 2
    assert lc.observe(7) == 2               # 7 > 8-2: hysteresis holds
    assert lc.observe(6) == 1               # 6 <= 8-2: down ONE level
    assert lc.observe(6) == 1               # still >= step-1 watermark
    assert lc.observe(2) == 0               # 2 <= 4-2: recovered
    snap = lc.snapshot()
    assert snap["transitions"] == 4
    assert snap["degraded_batches"] == 5    # every level>0 observation
    assert snap["level"] == 0 and snap["probe_budget"] is None


def test_load_controller_jumps_straight_to_deepest_watermark():
    lc = LoadController(DegradePolicy(steps=((4, 8), (8, 2)), hysteresis=2))
    assert lc.observe(100) == 2             # no level-at-a-time climb
    assert lc.transitions == 1


# ---------------------------------------------------------------------------
# fault injection: seeded, replayable, independent streams
# ---------------------------------------------------------------------------

def _drive(inj, n=32):
    """A fixed decision-site sequence; returns the observable outcomes."""
    spikes, errors = [], []
    for _ in range(n):
        try:
            inj.around_execute(lambda: "ok")
        except InjectedKernelError:
            errors.append(True)
        else:
            errors.append(False)
    return errors, dict(inj.counters)


def test_fault_injection_is_seed_deterministic():
    spec = FaultSpec(seed=7, latency_spike_p=0.3, latency_spike_ms=1.0,
                     kernel_error_p=0.2, poison_bind_p=0.5)
    sleeps_a, sleeps_b = [], []
    a = FaultInjector(spec, sleep_fn=sleeps_a.append)
    b = FaultInjector(spec, sleep_fn=sleeps_b.append)
    binds = {"qv": np.ones(4, np.float32)}
    pa = [a.maybe_poison(binds)[1] for _ in range(16)]
    pb = [b.maybe_poison(binds)[1] for _ in range(16)]
    assert pa == pb and any(pa)
    ea, ca = _drive(a)
    eb, cb = _drive(b)
    assert ea == eb and ca == cb and sleeps_a == sleeps_b
    assert ca["kernel_errors"] == sum(ea) > 0
    assert ca["latency_spikes"] == len(sleeps_a) > 0


def test_fault_streams_are_independent():
    # enabling kernel errors must not shift the latency draw sequence
    lat_only = FaultInjector(FaultSpec(seed=3, latency_spike_p=0.4),
                             sleep_fn=lambda s: None)
    both = FaultInjector(FaultSpec(seed=3, latency_spike_p=0.4,
                                   kernel_error_p=0.9),
                         sleep_fn=lambda s: None)
    _drive(lat_only)
    _drive(both)
    assert (lat_only.counters["latency_spikes"]
            == both.counters["latency_spikes"] > 0)


def test_maybe_poison_nans_first_float_bind_only():
    inj = FaultInjector(FaultSpec(seed=0, poison_bind_p=1.0))
    binds = {"qv": np.ones(4, np.float32), "p": np.float32(0.5)}
    out, poisoned = inj.maybe_poison(binds)
    assert poisoned and np.isnan(out["qv"]).all()
    assert out["p"] == binds["p"]           # scalars / later binds untouched
    assert np.isfinite(binds["qv"]).all()   # caller's dict never mutated
    with pytest.raises(PoisonedBindError):
        validate_binds(out)                 # the door catches the poison
    # no float-array bind to poison: draw consumed, nothing corrupted
    out2, poisoned2 = inj.maybe_poison({"k": np.int32(3)})
    assert not poisoned2 and out2 == {"k": np.int32(3)}
    assert inj.counters["poisoned_binds"] == 1


def test_wrap_fires_bump_before_execute():
    fired = []
    inj = FaultInjector(FaultSpec(seed=0, catalog_bump_p=1.0),
                        bump_fn=lambda: fired.append(len(fired)))
    calls = []
    wrapped = inj.wrap(lambda bl: calls.append(bl) or "out")
    assert wrapped(["b"]) == "out"
    assert fired == [0] and calls == [["b"]]
    assert inj.counters["catalog_bumps"] == 1


# ---------------------------------------------------------------------------
# degraded execution reports through explain()
# ---------------------------------------------------------------------------

def test_resilient_scheduler_degrades_and_reports(env):
    _cat, stmt, qs = env
    sched = ResilientScheduler(
        stmt, SchedulerConfig(max_batch=8, max_wait_ms=50.0),
        policy=DegradePolicy(steps=((4, 2),), hysteresis=0))
    rids = [sched.submit_request(_binds(qs, i)) for i in range(6)]
    done = sched.flush()
    assert sorted(done) == sorted(rids)
    for rid in rids:
        rep = sched.result(rid).explain()
        assert rep.degraded == {"level": 1, "probe_budget": 2}
        assert "DEGRADED" in rep.render()
    snap = sched.snapshot()
    assert snap["executed"] == 6 and snap["batches"] == 1
    assert snap["load"]["degraded_batches"] == 1
    # shallow traffic runs at full effort and does NOT report degraded
    rid = sched.submit_request(_binds(qs, 0))
    sched.flush()
    assert sched.result(rid).explain().degraded is None


# ---------------------------------------------------------------------------
# QueryServer: the asyncio front door
# ---------------------------------------------------------------------------

def _serve_config(watermark, max_batch=4, max_wait_ms=100.0,
                  deadline_ms=None):
    return ServeConfig(
        admission=AdmissionConfig(max_queue_depth=watermark,
                                  retry_after_ms=5.0),
        scheduler=SchedulerConfig(max_batch=max_batch,
                                  max_wait_ms=max_wait_ms,
                                  default_deadline_ms=deadline_ms),
        policy=DegradePolicy(steps=((8, 4),), hysteresis=2),
        idle_tick_ms=5.0)


def test_query_server_backpressure_is_typed_and_counted(env):
    _cat, stmt, qs = env

    async def scenario():
        server = QueryServer(stmt, _serve_config(watermark=4))
        server.scheduler.warm(_binds(qs, 0), [1, 2, 4])
        async with server:
            outs = await asyncio.gather(
                *(server.submit(_binds(qs, i)) for i in range(12)),
                return_exceptions=True)
            snap = server.snapshot()
        return outs, snap

    outs, snap = asyncio.run(scenario())
    ok = [o for o in outs if not isinstance(o, BaseException)]
    bp = [o for o in outs if isinstance(o, BackpressureError)]
    # the gather submits all 12 before any batch resolves: exactly the
    # watermark's worth admitted, the rest explicitly rejected at the door
    assert len(ok) == 4 and len(bp) == 8
    assert all(e.retry_after_ms > 0 for e in bp)
    assert all(np.asarray(r.ids).shape == (4,) for r in ok)
    assert snap["admission"] == {"admitted": 4, "rejected": 8}
    assert snap["executed"] == 4 and snap["in_flight"] == 0


def test_query_server_rejects_poison_and_sheds_deadlines(env):
    _cat, stmt, qs = env

    async def scenario():
        server = QueryServer(stmt, _serve_config(watermark=64))
        server.scheduler.warm(_binds(qs, 0), [1])
        bad = dict(_binds(qs, 0))
        bad["qv"] = np.full_like(bad["qv"], np.nan)
        async with server:
            with pytest.raises(PoisonedBindError):
                await server.submit(bad)
            # a deadline in the past is shed at the first poll, typed
            with pytest.raises(DeadlineExceededError):
                await server.submit(_binds(qs, 1), deadline_ms=1e-3)
            ok = await server.submit(_binds(qs, 2))
        return ok, server.snapshot()

    ok, snap = asyncio.run(scenario())
    assert np.asarray(ok.ids).shape == (4,)
    assert snap["shed_deadline"] == 1
    assert snap["admission"]["admitted"] == 3     # poison admitted-then-shot
    assert snap["in_flight"] == 0


def test_query_server_lifecycle_guards(env):
    _cat, stmt, qs = env

    async def scenario():
        server = QueryServer(stmt, _serve_config(watermark=4))
        with pytest.raises(RuntimeError, match="not running"):
            await server.submit(_binds(qs, 0))
        async with server:
            with pytest.raises(RuntimeError, match="already started"):
                await server.start()
        await server.stop()                 # second stop is a no-op

    asyncio.run(scenario())
