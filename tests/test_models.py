"""Per-arch smoke tests: reduced configs, one forward + one train step on CPU,
shape and finiteness assertions (the assignment's smoke contract)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import forward, init_params, lm_loss
from repro.training import (AdamWConfig, TrainState, TrainStepConfig,
                            adamw_init, build_train_step)


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.key(seed)
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size,
                                  dtype=jnp.int32)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    emb = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    return {"embeds": emb, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    kw = ({"tokens": batch["tokens"]} if cfg.input_mode == "tokens"
          else {"embeds": batch["embeds"]})
    logits, aux = forward(params, cfg, **kw)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(build_train_step(cfg, opt_cfg))
    state = TrainState.create(params, adamw_init(opt_cfg, params),
                              jax.random.key(1))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state.step) == 1
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


def test_microbatch_accumulation_equivalent():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_params(jax.random.key(0), cfg)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    batch = _batch(cfg, b=4, s=16)
    s1 = TrainState.create(params, adamw_init(opt_cfg, params),
                           jax.random.key(1))
    s2 = TrainState.create(params, adamw_init(opt_cfg, params),
                           jax.random.key(1))
    one = jax.jit(build_train_step(cfg, opt_cfg, TrainStepConfig(1)))
    four = jax.jit(build_train_step(cfg, opt_cfg, TrainStepConfig(4)))
    s1, m1 = one(s1, batch)
    s2, m2 = four(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)


def test_num_params_estimate_matches_actual():
    for arch in ("qwen2-1.5b", "mamba2-370m", "moonshot-v1-16b-a3b"):
        cfg = get_config(arch, smoke=True)
        params = init_params(jax.random.key(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.num_params_estimate()
        assert abs(est - actual) / actual < 0.12, (arch, est, actual)
