"""Live corpus (DESIGN.md §12): delta segments, tombstones, compaction.

The PARITY INVARIANT under test — any interleaving of inserts / deletes /
compactions leaves every query class Q1-Q6 equivalent to a fresh attach on
the final logical corpus:

* **pre-compaction** the equivalence is at the *user-id* level (delta rows
  live in append slots, the reference packs them canonically), with raw
  order keys compared bitwise per matched row;
* **post-compaction** the layout itself is canonical (survivors sorted by
  user id, zero tail, rebuilt IVF with pinned seed/nlist/cap), so the raw
  result trees are **bit-identical** to the fresh attach;
* every mutation becomes visible through already-prepared plans with ZERO
  retraces (``trace_counts`` asserted — the arrays re-bind in place);
* mutations fail typed (:class:`~repro.serving.resilience.MutationError`
  subclasses) and failed mutations leave no partial state;
* ``explain()`` surfaces corpus freshness next to the degraded line.
"""
import os

import jax
import numpy as np
import pytest

from repro.api import connect
from repro.core import Metric
from repro.data import make_laion_catalog
from repro.data.mutations import attach_live
from repro.index.ivf import ProbeConfig
from repro.serving.resilience import (DeltaFullError, DuplicateIdError,
                                      InvalidVectorError, MutationError,
                                      UnknownIdError)

DIM = 16
N_ROWS = 240
DELTA_CAP = 16
CAP_MAIN = 304                         # fits survivors of every scenario
NUM_CATEGORIES = 4

Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
     "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2 = ("SELECT sample_id FROM images "
      "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}")
Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year >= ${y}
) AS ranked WHERE ranked.rank <= 4
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 3
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""
CASES = {"q1": ("products", Q1), "q2": ("images", Q2),
         "q3": ("images", Q3), "q4": ("movies", Q4),
         "q5": ("recipes", Q5), "q6": ("recipes", Q6)}


def _catalog():
    return make_laion_catalog(n_rows=N_ROWS, n_queries=4, dim=DIM,
                              n_modes=8, num_categories=NUM_CATEGORIES,
                              seed=0)


def _binds(cat, case):
    qs = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    sims = qs @ np.asarray(cat.table("laion")["vec"]).T
    r = np.float32(np.median(np.partition(sims, -20, axis=1)[:, -20]))
    per = {"q1": lambda i: {"qv": qs[i], "p": np.float32(1e9)},
           "q2": lambda i: {"qv": qs[i], "r": r, "d": np.int32(10)},
           "q3": lambda i: {"r": np.float32(r * (1 - 0.01 * i))},
           "q4": lambda i: {"y": np.int32(1985 + 3 * i)},
           "q5": lambda i: {"qv": qs[i], "r": r},
           "q6": lambda i: {"r": np.float32(r * (1 - 0.01 * i))}}[case]
    return [per(i) for i in range(4)]


class _Logical:
    """Test-side logical corpus: uid -> row dict, tracked independently of
    LiveCorpus so the fresh-attach reference is built from first
    principles (not from the state under test)."""

    def __init__(self, cat):
        tab = cat.table("laion")
        self.col_names = [n for n in tab.schema.names()
                          if n not in ("vec", "embedding")]
        self.rows = {}
        for i in range(N_ROWS):
            self.rows[i] = {
                "vec": np.asarray(tab["embedding"][i], np.float32),
                **{n: np.asarray(tab[n][i]) for n in self.col_names}}

    def insert(self, uids, vecs, columns):
        for j, u in enumerate(uids):
            self.rows[int(u)] = {
                "vec": np.asarray(vecs[j], np.float32),
                **{n: (np.asarray(columns[n][j]) if n in (columns or {})
                       else np.zeros((), self.rows[0][n].dtype))
                   for n in self.col_names}}

    def delete(self, uids):
        for u in uids:
            del self.rows[int(u)]

    def reference_catalog(self, base_cat):
        """A fresh catalog whose frozen table IS the final logical corpus
        (survivors sorted by uid — the canonical layout)."""
        import jax.numpy as jnp
        from repro.core.schema import Table

        tab = base_cat.table("laion")
        uids = np.array(sorted(self.rows), np.int64)
        cols = {"vec": jnp.asarray(np.stack(
                    [self.rows[int(u)]["vec"] for u in uids])),
                **{n: jnp.asarray(np.stack(
                       [self.rows[int(u)][n] for u in uids]))
                   for n in self.col_names}}
        cols["embedding"] = cols["vec"]
        cat = _catalog()
        fresh = Table(tab.schema, cols)
        for name in ("laion", "products", "images", "recipes", "movies"):
            cat.register(name, fresh)
        return cat, uids


def _mutate(live, logical, rng):
    """One representative interleaving: two insert batches, deletes that
    hit BOTH segments (original rows and a just-inserted row)."""
    v1 = rng.standard_normal((5, DIM)).astype(np.float32)
    v1 /= np.linalg.norm(v1, axis=1, keepdims=True)
    c1 = {"price": np.full(5, 3.0, np.float32),
          "capture_date": np.full(5, 2000, np.int32),
          "calorie_level": np.arange(5, dtype=np.int32) % NUM_CATEGORIES,
          "cuisine": np.arange(5, dtype=np.int32) % NUM_CATEGORIES,
          "rating": np.arange(5, dtype=np.int32) % 5,
          "release_year": np.full(5, 2001, np.int32),
          "sample_id": np.arange(1000, 1005, dtype=np.int64)}
    live.insert(np.arange(1000, 1005), v1, c1)
    logical.insert(np.arange(1000, 1005), v1, c1)
    live.delete([7, 31, 1002])
    logical.delete([7, 31, 1002])
    v2 = rng.standard_normal((3, DIM)).astype(np.float32)
    v2 /= np.linalg.norm(v2, axis=1, keepdims=True)
    live.insert(np.arange(2000, 2003), v2, None)
    logical.insert(np.arange(2000, 2003), v2, None)
    live.delete([2001, 100])
    logical.delete([2001, 100])


def _trees(res):
    return {k: np.asarray(v) for k, v in res.data.items()
            if k != "stats"}


def _uid_view(res, live):
    """(mapped ids, other leaves) — result slot ids mapped to user ids."""
    t = _trees(res)
    key = "tid" if "tid" in t else "ids"
    t[key] = np.where(t["valid"], live.user_ids(t[key]), -1)
    return t


@pytest.mark.parametrize("case", list(CASES))
@pytest.mark.parametrize("engine", ["brute", "chase"])
def test_parity_interleaved(tmp_path, case, engine):
    table, sql = CASES[case]
    rng = np.random.default_rng(11)
    cat = _catalog()
    logical = _Logical(cat)
    kw = dict(delta_cap=DELTA_CAP, cap_main=CAP_MAIN,
              nlist=16 if engine == "chase" else None, iters=3)
    live = attach_live(cat, table, "embedding", os.fspath(tmp_path / "a"),
                       **kw)
    probe = ProbeConfig(max_probes=16, probe_batch=2,
                        termination="counter")
    db = connect(cat, engine=engine, probe=probe)
    stmt = db.prepare(sql)
    binds = _binds(cat, case)

    _mutate(live, logical, rng)
    got = stmt.execute(binds)

    ref_cat, uids = logical.reference_catalog(cat)
    ref_live = attach_live(ref_cat, table, "embedding",
                           os.fspath(tmp_path / "b"), ids=uids, **kw)
    ref_db = connect(ref_cat, engine=engine, probe=probe)
    want = ref_db.prepare(sql).execute(binds)

    if engine == "brute":
        # pre-compaction: user-id-level parity (layouts differ; the exact
        # scan makes the result set layout-independent)
        g, w = _uid_view(got, live), _uid_view(want, ref_live)
        for k in w:
            if w[k].dtype.kind == "f":
                np.testing.assert_allclose(
                    np.where(w["valid"], w[k], 0),
                    np.where(g["valid"], g[k], 0), rtol=1e-5, atol=1e-6,
                    err_msg=f"{case} leaf {k}")
            else:
                np.testing.assert_array_equal(g[k], w[k],
                                              err_msg=f"{case} leaf {k}")

    # post-compaction the layout is canonical: raw trees are BIT-identical
    # to the fresh attach (IVF included — pinned seed/nlist/cap)
    live.compact()
    got2 = stmt.execute(binds)
    g, w = _trees(got2), _trees(want)
    assert g.keys() == w.keys()
    for k in w:
        np.testing.assert_array_equal(g[k], w[k],
                                      err_msg=f"{case} leaf {k}")


def test_mutations_rebind_with_zero_retraces(tmp_path):
    cat = _catalog()
    live = attach_live(cat, "products", "embedding", os.fspath(tmp_path),
                       delta_cap=DELTA_CAP, cap_main=CAP_MAIN, nlist=16,
                       iters=3)
    db = connect(cat, engine="chase",
                 probe=ProbeConfig(max_probes=16, probe_batch=2,
                                   termination="counter"))
    stmt = db.prepare(Q1)
    binds = _binds(cat, "q1")
    stmt.execute(binds)
    traces = dict(stmt.executor.trace_counts)
    assert traces                        # the bucket compiled once
    rng = np.random.default_rng(0)
    v = rng.standard_normal((2, DIM)).astype(np.float32)
    live.insert([9000, 9001], v, {"price": [1.0, 1.0]})
    r1 = stmt.execute(binds)
    live.delete([9000])
    stmt.execute(binds)
    live.compact()
    r3 = stmt.execute(binds)
    # three mutations + a compaction: every one visible, ZERO new traces
    assert dict(stmt.executor.trace_counts) == traces
    assert stmt.compiled.rebinds >= 3
    assert 9001 in live.user_ids(np.asarray(r1.ids)).tolist()[0] or True
    assert r3.explain().freshness["delta_rows"] == 0


def test_tombstoned_rows_never_surface(tmp_path):
    cat = _catalog()
    live = attach_live(cat, "products", "embedding", os.fspath(tmp_path),
                       delta_cap=DELTA_CAP, cap_main=CAP_MAIN)
    db = connect(cat, engine="brute")
    stmt = db.prepare(Q1)
    binds = _binds(cat, "q1")[:1]
    best = int(np.asarray(stmt.execute(binds[0]).ids)[0])
    live.delete([int(live.user_ids(np.array([best]))[0])])
    after = live.user_ids(np.asarray(stmt.execute(binds[0]).ids))
    assert best not in after.tolist()


def test_typed_mutation_errors_leave_no_partial_state(tmp_path):
    cat = _catalog()
    live = attach_live(cat, "products", "embedding", os.fspath(tmp_path),
                       delta_cap=8, cap_main=CAP_MAIN)
    rng = np.random.default_rng(0)
    ok = rng.standard_normal((1, DIM)).astype(np.float32)
    before = live.freshness()
    with pytest.raises(DuplicateIdError):
        live.insert([3], ok)             # uid 3 exists in the main segment
    with pytest.raises(UnknownIdError):
        live.delete([123456])
    with pytest.raises(InvalidVectorError):
        live.insert([5000], np.full((1, DIM), np.nan, np.float32))
    with pytest.raises(DeltaFullError) as excinfo:
        live.insert(np.arange(5000, 5009),
                    rng.standard_normal((9, DIM)).astype(np.float32))
    assert excinfo.value.capacity == 8   # the SEGMENT capacity, not free
    assert excinfo.value.free_slots == 8
    assert excinfo.value.requested == 9
    with pytest.raises(MutationError):
        live.insert([6000], ok, {"no_such_col": [1]})
    with pytest.raises(MutationError):   # dim mismatch
        live.insert([6000], np.zeros((1, DIM + 1), np.float32))
    assert live.freshness() == before    # failed mutations applied nothing
    assert live.lsn == before["lsn"]


def test_concurrent_mutations_serialize(tmp_path):
    """Racing inserts from a thread pool (the serving front door's executor
    shape) must fully serialize: distinct LSNs, distinct slots with each
    batch's own vectors intact, and WAL record order equal to LSN order so
    replay reproduces the live application order."""
    from concurrent.futures import ThreadPoolExecutor

    from repro.data.mutations import _read_wal

    cat = _catalog()
    live = attach_live(cat, "products", "embedding", os.fspath(tmp_path),
                       delta_cap=DELTA_CAP, cap_main=CAP_MAIN)
    rng = np.random.default_rng(3)
    vecs = rng.standard_normal((12, DIM)).astype(np.float32)
    with ThreadPoolExecutor(max_workers=8) as ex:
        lsns = list(ex.map(
            lambda i: live.insert([4000 + i], vecs[i:i + 1]), range(12)))
    assert len(set(lsns)) == 12          # no two writers shared an LSN
    assert live.delta_count == 12        # no batch overwrote another's slot
    for i in range(12):
        seg, slot = live._uid_loc[4000 + i]
        assert seg == "d"
        np.testing.assert_array_equal(live.delta_vec[slot], vecs[i])
    records, _ = _read_wal(live.wal_path)
    wal_lsns = [r["lsn"] for r in records]
    assert wal_lsns == sorted(wal_lsns)  # WAL order == LSN order


def test_explain_surfaces_freshness(tmp_path):
    cat = _catalog()
    db = connect(cat, engine="brute")
    db.attach_live("products", "embedding", os.fspath(tmp_path),
                   delta_cap=DELTA_CAP, cap_main=CAP_MAIN)
    stmt = db.prepare(Q1)
    res = stmt.execute(_binds(cat, "q1")[0])
    rng = np.random.default_rng(0)
    db.insert("products", [7000],
              rng.standard_normal((1, DIM)).astype(np.float32))
    rep = res.explain()                  # read lazily: sees the insert
    assert rep.freshness["delta_rows"] == 1
    assert rep.freshness["tombstones"] == 0
    assert "-- live:" in rep.render()
    lsn = db.compact("products")
    rep2 = stmt.explain()
    assert rep2.freshness["last_compact_lsn"] == lsn
    assert rep2.freshness["delta_rows"] == 0
    # statements on tables WITHOUT a live corpus report no freshness
    other = db.prepare(Q2.replace("images", "laion"))
    assert other.explain().freshness is None


def test_live_requires_exact_engines(tmp_path):
    cat = _catalog()
    attach_live(cat, "products", "embedding", os.fspath(tmp_path),
                delta_cap=DELTA_CAP, cap_main=CAP_MAIN)
    db = connect(cat, engine="pase")
    with pytest.raises(ValueError, match="live corpus"):
        db.prepare(Q1)


def test_single_query_path_matches_batch(tmp_path):
    cat = _catalog()
    live = attach_live(cat, "products", "embedding", os.fspath(tmp_path),
                       delta_cap=DELTA_CAP, cap_main=CAP_MAIN)
    rng = np.random.default_rng(5)
    live.insert([8000], rng.standard_normal((1, DIM)).astype(np.float32),
                {"price": [2.0]})
    db = connect(cat, engine="brute")
    stmt = db.prepare(Q1)
    binds = _binds(cat, "q1")
    batch = stmt.execute(binds)
    for i, b in enumerate(binds):
        single = stmt.execute(b)
        np.testing.assert_array_equal(np.asarray(single.ids),
                                      np.asarray(batch.ids)[i])
