"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here —
smoke tests must see 1 device; multi-device tests use subprocesses."""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def laion_catalog():
    from repro.core.schema import Metric
    from repro.data import make_laion_catalog
    from repro.index import build_ivf

    cat = make_laion_catalog(n_rows=4000, n_queries=8, dim=32, n_modes=24,
                             num_categories=6, seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=32,
                    metric=Metric.INNER_PRODUCT, iters=4)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    return cat


@pytest.fixture(scope="session")
def query_vec(laion_catalog):
    return np.asarray(laion_catalog.table("queries")["embedding"][0])
