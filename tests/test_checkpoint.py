"""Checkpointing: roundtrip, async, atomic commit, GC, auto-resume."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.configs import get_config
from repro.models import init_params
from repro.training import AdamWConfig, TrainState, adamw_init


def _state(seed=0):
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_params(jax.random.key(seed), cfg)
    opt = adamw_init(AdamWConfig(), params)
    return TrainState.create(params, opt, jax.random.key(seed))


def _as_np(x):
    try:
        if jax.dtypes.issubdtype(x.dtype, jax.dtypes.prng_key):
            return np.asarray(jax.random.key_data(x))
    except (AttributeError, TypeError):
        pass
    return np.asarray(x)


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(_as_np(x), _as_np(y))


def test_roundtrip(tmp_path):
    state = _state()
    save(str(tmp_path), 7, state)
    restored = restore(str(tmp_path), 7, jax.eval_shape(lambda: state))
    _assert_tree_equal(state, restored)


def test_async_checkpointer_and_gc(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep_last_k=2)
    state = _state()
    for step in (1, 2, 3, 4):
        ckpt.save_async(step, state)
    ckpt.wait()
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]                     # keep_last_k=2


def test_uncommitted_step_invisible(tmp_path):
    state = _state()
    save(str(tmp_path), 5, state)
    # simulate a crash mid-save: directory without manifest
    os.makedirs(tmp_path / "step_9")
    assert latest_step(str(tmp_path)) == 5     # 9 has no manifest


def test_restore_shape_mismatch_raises(tmp_path):
    state = _state()
    save(str(tmp_path), 1, state)
    bad = jax.eval_shape(lambda: _state())
    bad_leaves, treedef = jax.tree_util.tree_flatten(bad)
    bad_leaves[0] = jax.ShapeDtypeStruct((1, 2, 3), jnp.float32)
    bad = jax.tree_util.tree_unflatten(treedef, bad_leaves)
    with pytest.raises((ValueError, KeyError)):
        restore(str(tmp_path), 1, bad)


def test_resume_after_restart_reproduces_training(tmp_path):
    """Fault-tolerance contract: train 6 steps straight == train 3, crash,
    resume from checkpoint, train 3 more (deterministic data pipeline)."""
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.training import TrainStepConfig, build_train_step

    cfg = get_config("qwen2-1.5b", smoke=True)
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=6)
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=16,
                                  vocab_size=cfg.vocab_size))
    step_fn = jax.jit(build_train_step(cfg, opt_cfg))

    def fresh():
        params = init_params(jax.random.key(0), cfg)
        return TrainState.create(params, adamw_init(opt_cfg, params),
                                 jax.random.key(0))

    # run A: straight through
    sa = fresh()
    for i in range(6):
        sa, _ = step_fn(sa, data.batch_at(i))

    # run B: crash after 3, restore, continue
    sb = fresh()
    for i in range(3):
        sb, _ = step_fn(sb, data.batch_at(i))
    save(str(tmp_path), 3, sb)
    sb2 = restore(str(tmp_path), 3, jax.eval_shape(lambda: sb))
    for i in range(3, 6):
        sb2, _ = step_fn(sb2, data.batch_at(i))

    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
