"""Pallas kernel validation: shape/dtype sweeps vs the jnp oracles
(interpret=True on CPU; the kernels target TPU BlockSpecs)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.expr import order_key
from repro.core.schema import Metric
from repro.kernels import ref
from repro.kernels.ops import fused_range_scan, fused_scan_topk, pairwise_keys

METRICS = [Metric.INNER_PRODUCT, Metric.L2, Metric.COSINE]
SHAPES = [(1000, 48, 10), (2048, 128, 50), (777, 33, 7), (64, 8, 5)]


def _data(n, d, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    c = rng.standard_normal((n, d)).astype(dtype)
    q = rng.standard_normal((d,)).astype(dtype)
    m = rng.random(n) < 0.5
    return jnp.asarray(c), jnp.asarray(q), jnp.asarray(m)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n,d,k", SHAPES)
def test_scan_topk_matches_ref(metric, n, d, k):
    c, q, m = _data(n, d)
    ids, sims, valid = fused_scan_topk(c, q, k, m, metric, block_n=256)
    rids, rkeys, rvalid = ref.scan_topk_ref(c, q, k, m, metric)
    assert np.array_equal(np.asarray(valid), np.asarray(rvalid))
    kk = order_key(metric, sims)
    np.testing.assert_allclose(np.asarray(kk)[np.asarray(valid)],
                               np.asarray(rkeys)[np.asarray(rvalid)],
                               rtol=2e-4, atol=2e-4)
    # ids must satisfy the mask
    got = np.asarray(ids)[np.asarray(valid)]
    assert np.asarray(m)[got].all()


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n,d", [(1000, 48), (513, 96)])
def test_range_scan_matches_ref(metric, n, d):
    c, q, m = _data(n, d, seed=1)
    keys = np.asarray(ref.keys_ref(c, q, metric))
    srt = np.sort(keys)
    # radius strictly between adjacent keys => no boundary-tie flakiness
    radius_key = float((srt[n // 3] + srt[n // 3 + 1]) / 2.0)
    raw_radius = -radius_key if metric.is_similarity() else radius_key
    hit, raw, cnt = fused_range_scan(c, q, raw_radius, m, metric, block_n=128)
    rhit, _ = ref.range_scan_ref(c, q, radius_key, m, metric)
    assert np.array_equal(np.asarray(hit), np.asarray(rhit))
    assert int(cnt) == int(np.asarray(rhit).sum())


@pytest.mark.parametrize("metric", METRICS)
def test_pairwise_keys_matches_ref(metric):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((40, 72)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((300, 72)).astype(np.float32))
    got = pairwise_keys(q, c, metric, block_q=16, block_c=128)
    want = ref.pairwise_keys_ref(q, c, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_scan_topk_bf16_inputs():
    c, q, m = _data(512, 64, seed=3)
    ids32, sims32, _ = fused_scan_topk(c, q, 8, m, Metric.INNER_PRODUCT,
                                       block_n=128)
    ids16, sims16, _ = fused_scan_topk(c.astype(jnp.bfloat16),
                                       q.astype(jnp.bfloat16), 8, m,
                                       Metric.INNER_PRODUCT, block_n=128)
    # bf16 inputs upcast inside the kernel; top sets mostly agree
    overlap = len(set(np.asarray(ids32).tolist())
                  & set(np.asarray(ids16).tolist()))
    assert overlap >= 6


def test_no_mask_means_all_rows():
    c, q, _ = _data(256, 32, seed=4)
    ids, sims, valid = fused_scan_topk(c, q, 5, None, Metric.L2, block_n=128)
    assert bool(valid.all())
    rids, rkeys, _ = ref.scan_topk_ref(c, q, 5, None, Metric.L2)
    np.testing.assert_allclose(np.sort(np.asarray(sims)),
                               np.sort(np.asarray(rkeys)), rtol=1e-5)
