"""SQL parser: the paper's six templates must parse verbatim (Fig. 2)."""
import pytest

from repro.core.expr import BoolOp, Cmp, Column, Distance, Param
from repro.core.plan import (Filter, Join, Limit, OrderBy, Project, Scan,
                             WindowRank, walk_plan)
from repro.core.sql import parse_sql

Q1 = """
SELECT id FROM products
WHERE category = ${cat} AND price < 100
ORDER BY DISTANCE(embedding, ${query_embedding})
LIMIT 50
"""

Q2 = """
SELECT id FROM images
WHERE DISTANCE(embedding, ${query_embedding}) <= ${THRESHOLD}
AND location = 'US' AND capture_date > '2023-07-01'
"""

Q3 = """
SELECT queries.id AS qid, images.id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${THRESHOLD}
AND images.capture_date > queries.capture_date
"""

Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year > users.preferred_release_year
) AS ranked WHERE ranked.rank <= 50
"""

Q5 = """
SELECT qid, category FROM (
 SELECT id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${query_embedding})) AS rank
 FROM recipes
 WHERE DISTANCE(embedding, ${query_embedding}) <= ${R1}
 AND cuisine <> 'Italian'
) AS ranked WHERE ranked.rank <= 10
"""

Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${R1}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 10
"""

ALL = {"Q1": Q1, "Q2": Q2, "Q3": Q3, "Q4": Q4, "Q5": Q5, "Q6": Q6}


@pytest.mark.parametrize("name", list(ALL))
def test_templates_parse(name):
    plan = parse_sql(ALL[name])
    assert plan is not None
    assert plan.pretty()


def test_q1_structure():
    plan = parse_sql(Q1)
    kinds = [type(n).__name__ for n in walk_plan(plan)]
    assert kinds == ["Project", "Limit", "OrderBy", "Filter", "Scan"]
    order = next(n for n in walk_plan(plan) if isinstance(n, OrderBy))
    assert isinstance(order.key, Distance)
    lim = next(n for n in walk_plan(plan) if isinstance(n, Limit))
    assert lim.k == 50


def test_q2_distance_in_where():
    plan = parse_sql(Q2)
    filt = next(n for n in walk_plan(plan) if isinstance(n, Filter))
    assert isinstance(filt.predicate, BoolOp)


def test_q4_window():
    plan = parse_sql(Q4)
    win = next(n for n in walk_plan(plan) if isinstance(n, WindowRank))
    assert len(win.partition_by) == 1
    assert isinstance(win.order_by, Distance)
    assert win.rank_name == "rank"
    join = next(n for n in walk_plan(plan) if isinstance(n, Join))
    assert isinstance(join.left, Scan) and join.left.table == "users"


def test_q6_two_partition_keys():
    plan = parse_sql(Q6)
    win = next(n for n in walk_plan(plan) if isinstance(n, WindowRank))
    assert len(win.partition_by) == 2


def test_param_placeholders():
    plan = parse_sql("SELECT a FROM t WHERE b < ${x} LIMIT ${K}")
    lim = next(n for n in walk_plan(plan) if isinstance(n, Limit))
    assert lim.k == "K"
    filt = next(n for n in walk_plan(plan) if isinstance(n, Filter))
    assert isinstance(filt.predicate.rhs, Param)


def test_string_literals_and_escapes():
    plan = parse_sql("SELECT a FROM t WHERE s = 'it''s'")
    filt = next(n for n in walk_plan(plan) if isinstance(n, Filter))
    assert filt.predicate.rhs.value == "it's"


def test_syntax_error():
    with pytest.raises(SyntaxError):
        parse_sql("SELECT FROM WHERE")
