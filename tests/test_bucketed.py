"""Size-bucketed execution stack (DESIGN.md §8): bucket-padding parity,
pad-query inertness, and the compile-once-per-bucket contract.

Contracts under test:
* ``execute_bucketed`` (Q padded up to the enclosing power-of-two bucket,
  outputs sliced back) is bit-identical to the exact-shape ``execute_batch``
  for EVERY query class, on both the IVF and the fused-kernel flat paths —
  the ``valid`` lane threads through kernels (mask layout) and probes
  (``active`` init) without perturbing real queries.
* pad queries are inert: empty results, all-False validity, and zero
  probe/distance counters (observable via ``BucketedExecutor.run_padded``).
* at most ONE executable exists per (plan, bucket) pair: Q=3 and Q=4 share
  the bucket-4 executable (``trace_counts`` stays 1), Q=9 adds bucket 16.
* ``ProbeConfig.probe_budget`` is a user-facing knob on every probe path.
* ``_stack_binds`` rejects ragged ``binds_list`` with a clear error naming
  the offending key; ``explain()`` reports the actual batch-lowering reason.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import EngineOptions, Metric, compile_query
from repro.core.compiler import _bucket_for
from repro.core.physical import BATCH_BUILDERS
from repro.core.semantics import QueryClass
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig

PROBE = ProbeConfig(max_probes=16, capacity=128, termination="bound",
                    probe_batch=2)

Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2 = ("SELECT sample_id FROM images "
      "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}")
Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year >= ${y}
) AS ranked WHERE ranked.rank <= 4
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 3
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""


@pytest.fixture(scope="module")
def env():
    from repro.data import make_laion_catalog

    cat = make_laion_catalog(n_rows=1200, n_queries=4, dim=16, n_modes=8,
                             num_categories=4, seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=16,
                    metric=Metric.INNER_PRODUCT, iters=3)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -30, axis=1)[:, -30]))
    return cat, radius


def _qvecs(cat, qn: int) -> np.ndarray:
    base = np.asarray(cat.table("queries")["embedding"])
    rng = np.random.default_rng(3)
    reps = -(-qn // base.shape[0])
    qs = np.tile(base, (reps, 1))[:qn]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _binds_for(case: str, cat, radius: float, qn: int) -> dict:
    rng = np.random.default_rng(7)
    price = np.asarray(cat.table("laion")["price"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    if case == "q1":
        return {"qv": _qvecs(cat, qn),
                "p": np.quantile(price,
                                 rng.uniform(0.3, 1.0, qn)).astype(
                                     np.float32)}
    if case == "q2":
        return {"qv": _qvecs(cat, qn),
                "r": (radius * rng.uniform(0.95, 1.0, qn)).astype(
                    np.float32),
                "d": np.quantile(dates, rng.uniform(0.2, 0.8, qn)).astype(
                    np.int32)}
    if case in ("q3", "q6"):
        return {"r": (radius * rng.uniform(0.95, 1.0, qn)).astype(
            np.float32)}
    if case == "q4":
        years = np.asarray(cat.table("movies")["release_year"])
        return {"y": np.quantile(years, rng.uniform(0.1, 0.6, qn)).astype(
            np.int32)}
    if case == "q5":
        return {"qv": _qvecs(cat, qn),
                "r": (radius * rng.uniform(0.95, 1.0, qn)).astype(
                    np.float32)}
    raise ValueError(case)


CASES = {
    "q1": (Q1, dict(engine="chase", probe=PROBE)),
    "q1_flat": (Q1, dict(engine="brute", use_pallas=True)),
    "q2": (Q2, dict(engine="chase", probe=PROBE)),
    "q2_flat": (Q2, dict(engine="brute", use_pallas=True)),
    "q3": (Q3, dict(engine="chase", probe=PROBE, max_pairs=64)),
    "q3_flat": (Q3, dict(engine="brute", use_pallas=True, max_pairs=64)),
    "q4": (Q4, dict(engine="chase", probe=PROBE)),
    "q5": (Q5, dict(engine="chase", probe=PROBE)),
    "q6": (Q6, dict(engine="chase", probe=PROBE, max_pairs=64)),
}


def _case_binds(name: str, cat, radius: float, qn: int) -> dict:
    return _binds_for(name.split("_")[0], cat, radius, qn)


def _assert_tree_equal(a, b, ctx=""):
    assert set(a) == set(b)
    for key in a:
        if key == "stats":
            for sk in a["stats"]:
                assert np.array_equal(np.asarray(a["stats"][sk]),
                                      np.asarray(b["stats"][sk])), \
                    f"{ctx}:stats.{sk}"
        else:
            assert np.array_equal(np.asarray(a[key]),
                                  np.asarray(b[key])), f"{ctx}:{key}"


# ---------------------------------------------------------------------------
# bucket-padding parity: Q=3 in bucket 4, Q=9 in bucket 16
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("qn", [3, 9])
def test_bucketed_matches_exact_batch(env, case, qn):
    cat, radius = env
    sql, opts = CASES[case]
    q = compile_query(sql, cat, EngineOptions(**opts))
    binds = _case_binds(case, cat, radius, qn)
    exact = q.execute_batch(**binds)
    bucketed = q.execute_bucketed(**binds)
    _assert_tree_equal(exact, bucketed, ctx=f"{case}@Q{qn}")
    leading = jax.tree.leaves(bucketed)[0].shape[0]
    assert leading == qn                        # outputs sliced back to Q


# ---------------------------------------------------------------------------
# pad queries are inert: zero counters, no results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_pad_queries_inert(env, case):
    cat, radius = env
    sql, opts = CASES[case]
    q = compile_query(sql, cat, EngineOptions(**opts))
    qn = 3
    binds = q._stack_binds(
        None, {k: jnp.asarray(v)
               for k, v in _case_binds(case, cat, radius, qn).items()})
    out, bucket, valid = q.executor.run_padded(binds, qn)
    assert bucket == 4 and not bool(np.asarray(valid)[qn:].any())
    for sk, v in out["stats"].items():
        assert (np.asarray(v)[qn:] == 0).all(), f"pad counters: {sk}"
    assert not np.asarray(out["valid"])[qn:].any()
    if "count" in out:
        assert (np.asarray(out["count"])[qn:] == 0).all()


# ---------------------------------------------------------------------------
# compile-once-per-bucket: trace counters
# ---------------------------------------------------------------------------

def test_one_executable_per_bucket(env):
    cat, radius = env
    q = compile_query(Q1, cat, EngineOptions(engine="chase", probe=PROBE))
    for qn in (3, 4, 9, 16, 2):
        q.execute_bucketed(**_case_binds("q1", cat, radius, qn))
    assert q.executor.buckets == [2, 4, 16]
    assert all(n == 1 for n in q.executor.trace_counts.values()), \
        q.executor.trace_counts
    # re-running any served size stays cached
    q.execute_bucketed(**_case_binds("q1", cat, radius, 3))
    assert q.executor.trace_counts[_bucket_for(3)] == 1


# ---------------------------------------------------------------------------
# probe_budget: the user-facing straggler valve
# ---------------------------------------------------------------------------

def test_probe_budget_knob_caps_probes(env):
    cat, radius = env
    budget = 3
    probe = ProbeConfig(max_probes=16, capacity=128, probe_batch=1,
                        probe_budget=budget)
    q = compile_query(Q1, cat, EngineOptions(engine="chase", probe=probe))
    binds = _case_binds("q1", cat, radius, 5)
    out = q.execute_batch(**binds)
    assert (np.asarray(out["stats"]["probes"]) <= budget).all()
    # runtime argument overrides the static knob
    out2 = q.execute_bucketed(probe_budget=2, **binds)
    assert (np.asarray(out2["stats"]["probes"]) <= 2).all()
    # the single-query path honors the knob too
    single = q(qv=binds["qv"][0], p=float(binds["p"][0]))
    assert int(np.asarray(single["stats"]["probes"])) <= budget


# ---------------------------------------------------------------------------
# satellite fixes: ragged binds_list, explain() reason
# ---------------------------------------------------------------------------

def test_ragged_binds_list_raises_clear_error(env):
    cat, radius = env
    q = compile_query(Q1, cat, EngineOptions(engine="chase", probe=PROBE))
    qv = _qvecs(cat, 2)
    good = {"qv": qv[0], "p": 1.0}
    bad = {"qv": qv[1], "radius": 1.0}          # wrong key name
    with pytest.raises(ValueError, match=r"binds_list\[1\].*'p'"):
        q.execute_batch(binds_list=[good, bad])
    with pytest.raises(ValueError, match="ragged"):
        q.execute_batch(binds_list=[good, {"qv": qv[1]}])


def test_explain_reports_actual_fallback_reason(env, monkeypatch):
    cat, radius = env
    # a class with NO registered batch builder must not be labeled as the
    # perleft join fallback
    monkeypatch.delitem(BATCH_BUILDERS, QueryClass.VKNN_SF)
    q = compile_query(Q1, cat, EngineOptions(engine="chase", probe=PROBE))
    assert not q.batch_native
    text = q.explain()
    assert "no native batch builder" in text
    assert "perleft join lowering" not in text
    # the vmap fallback still executes, and bucketed execution still slices
    binds = _case_binds("q1", cat, radius, 3)
    _assert_tree_equal(q.execute_batch(**binds),
                       q.execute_bucketed(**binds), ctx="fallback")


def test_explain_perleft_reason(env):
    cat, radius = env
    q = compile_query(Q3, cat, EngineOptions(engine="chase", probe=PROBE,
                                             join_lowering="perleft"))
    assert "perleft join lowering" in q.explain()
