"""Rewriter: plan shapes must match the paper's Figures 4b / 5b / 6b."""
from repro.core import analyze, parse_sql, rewrite
from repro.core.plan import (Filter, IndexScan, KnnSubquery, Limit, Map,
                             OrderBy, UpdateState, WindowRank, walk_plan)
from repro.core.expr import Column
from repro.core.rewriter import SIM_COL

from test_sql import Q4, Q5, Q6


def _rewrite(sql, catalog):
    return rewrite(analyze(parse_sql(sql), catalog))


def test_r1_map_operator(laion_catalog):
    """Fig 4b: IndexScan -> Map(__sim) -> OrderBy(__sim) -> Limit."""
    sql = """
    SELECT sample_id FROM products WHERE price < 100
    ORDER BY DISTANCE(embedding, ${q}) LIMIT 50
    """
    plan = _rewrite(sql, laion_catalog)
    nodes = list(walk_plan(plan))
    scan = next(n for n in nodes if isinstance(n, IndexScan))
    assert scan.mode == "topk"
    assert scan.emit_similarity
    assert scan.predicate is not None          # filter fused into the scan
    mp = next(n for n in nodes if isinstance(n, Map))
    assert mp.from_index_scan and mp.name == SIM_COL
    ob = next(n for n in nodes if isinstance(n, OrderBy))
    # the rewrite replaced the Distance key with the materialized column
    assert isinstance(ob.key, Column) and ob.key.name == SIM_COL
    assert any(isinstance(n, Limit) for n in nodes)


def test_r2_window_decoupling(laion_catalog):
    plan = _rewrite(Q4.replace("movies.id", "movies.sample_id"),
                    laion_catalog)
    nodes = list(walk_plan(plan))
    sub = next(n for n in nodes if isinstance(n, KnnSubquery))
    assert sub.k == 50
    assert sub.right_table == "movies"
    # the window operator is gone: scan/orderBy/limit fused per left row
    assert not any(isinstance(n, WindowRank) for n in nodes)


def test_r3_update_state(laion_catalog):
    sql = Q5.replace("SELECT id AS qid", "SELECT sample_id AS qid") \
            .replace("cuisine <> 'Italian'", "cuisine <> 3")
    plan = _rewrite(sql, laion_catalog)
    nodes = list(walk_plan(plan))
    upd = next(n for n in nodes if isinstance(n, UpdateState))
    scan = next(n for n in walk_plan(upd) if isinstance(n, IndexScan))
    assert scan.mode == "range"
    assert any(isinstance(n, WindowRank) for n in nodes)


def test_q6_join_update_state(laion_catalog):
    plan = _rewrite(Q6.replace("recipes.id", "recipes.sample_id"),
                    laion_catalog)
    nodes = list(walk_plan(plan))
    assert any(isinstance(n, UpdateState) for n in nodes)
    scan = next(n for n in nodes if isinstance(n, IndexScan))
    assert scan.mode == "range"


def test_dr_sf_uses_range_interface(laion_catalog):
    sql = """
    SELECT sample_id FROM images
    WHERE DISTANCE(embedding, ${q}) <= ${T} AND capture_date > 100
    """
    plan = _rewrite(sql, laion_catalog)
    scan = next(n for n in walk_plan(plan) if isinstance(n, IndexScan))
    assert scan.mode == "range"        # RangeSearch, not Topk (paper §5.2)
