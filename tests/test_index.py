"""IVF index: recall, Algorithm-1 range semantics, Algorithm-2 category
convergence, and exactness of the beyond-paper 'bound' termination."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.expr import order_key
from repro.core.schema import Metric
from repro.index import FlatIndex, build_ivf
from repro.index.ivf import (ProbeConfig, ivf_range, ivf_range_category,
                             ivf_topk)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    modes = rng.standard_normal((16, 24)).astype(np.float32)
    which = rng.integers(0, 16, size=3000)
    x = modes[which] + 0.3 * rng.standard_normal((3000, 24)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return jnp.asarray(x.astype(np.float32))


@pytest.fixture(scope="module")
def ivf(corpus):
    return build_ivf(jax.random.key(0), corpus, nlist=24,
                     metric=Metric.INNER_PRODUCT, iters=5)


@pytest.fixture(scope="module")
def flat(corpus):
    return FlatIndex(Metric.INNER_PRODUCT, corpus)


def _q(corpus, i=0):
    return corpus[i] + 0.01


def test_topk_recall_counter(corpus, ivf, flat):
    q = _q(corpus)
    gt_ids, _, _ = flat.topk(q, 20)
    ids, sims, valid, stats = ivf_topk(ivf, corpus, q, 20,
                                       cfg=ProbeConfig(max_probes=24))
    rec = len(set(np.asarray(ids).tolist())
              & set(np.asarray(gt_ids).tolist())) / 20
    assert rec >= 0.9
    assert int(stats["distance_evals"]) < corpus.shape[0]  # beat brute force


def test_topk_bound_termination_exact(corpus, ivf, flat):
    """Beyond-paper: radius-bound termination is EXACT when allowed to run."""
    q = _q(corpus, 1)
    gt_ids, _, _ = flat.topk(q, 10)
    cfg = ProbeConfig(max_probes=24, termination="bound")
    ids, _, valid, stats = ivf_topk(ivf, corpus, q, 10, cfg=cfg)
    assert set(np.asarray(ids).tolist()) == set(np.asarray(gt_ids).tolist())


def test_topk_filtered(corpus, ivf, flat):
    q = _q(corpus, 2)
    mask = jnp.asarray(np.random.default_rng(1).random(corpus.shape[0]) < 0.3)
    gt_ids, _, gt_valid = flat.topk(q, 15, mask)
    cfg = ProbeConfig(max_probes=24, termination="bound")
    ids, sims, valid, _ = ivf_topk(ivf, corpus, q, 15, mask, cfg)
    got = np.asarray(ids)[np.asarray(valid)]
    assert np.asarray(mask)[got].all()            # filter soundness
    gt = np.asarray(gt_ids)[np.asarray(gt_valid)]
    assert set(got.tolist()) == set(gt.tolist())  # exact under 'bound'


def _radius_for(flat, q, count=60):
    _, raw = flat.range_mask(q, -1e9)
    keys = np.sort(np.asarray(order_key(Metric.INNER_PRODUCT, raw)))
    return -float((keys[count] + keys[count + 1]) / 2)


def test_range_counter_vs_flat(corpus, ivf, flat):
    q = _q(corpus, 3)
    radius = _radius_for(flat, q)
    hit, _ = flat.range_mask(q, radius)
    gt = set(np.flatnonzero(np.asarray(hit)).tolist())
    ids, sims, valid, count, stats = ivf_range(
        ivf, corpus, q, radius, cfg=ProbeConfig(max_probes=24, capacity=512))
    got = set(np.asarray(ids)[np.asarray(valid)].tolist())
    assert got.issubset(gt | {-1})
    assert len(got & gt) / max(len(gt), 1) >= 0.9
    # all results really in range
    assert (np.asarray(sims)[np.asarray(valid)] >= radius - 1e-5).all()


def test_range_bound_exact(corpus, ivf, flat):
    q = _q(corpus, 4)
    radius = _radius_for(flat, q, 40)
    hit, _ = flat.range_mask(q, radius)
    gt = set(np.flatnonzero(np.asarray(hit)).tolist())
    cfg = ProbeConfig(max_probes=24, capacity=512, termination="bound")
    ids, _, valid, count, stats = ivf_range(ivf, corpus, q, radius, cfg=cfg)
    got = set(np.asarray(ids)[np.asarray(valid)].tolist())
    assert got == gt
    assert int(count) == len(gt)


def test_range_early_termination_probes_less(corpus, ivf, flat):
    """Alg.1's point: the scan must NOT visit all clusters for small radii."""
    q = _q(corpus, 5)
    radius = _radius_for(flat, q, 20)
    cfg = ProbeConfig(max_probes=24, capacity=512, out_range_stop=2)
    *_, stats = ivf_range(ivf, corpus, q, radius, cfg=cfg)
    assert int(stats["probes"]) < 24


def test_category_probe_per_category_topk(corpus, ivf, flat):
    q = _q(corpus, 6)
    C, K = 5, 4
    cats = jnp.asarray(
        np.random.default_rng(2).integers(0, C, corpus.shape[0]).astype(
            np.int32))
    radius = _radius_for(flat, q, 200)
    cfg = ProbeConfig(max_probes=24, capacity=1024, termination="bound",
                      num_categories=C, k_per_category=K)
    ids, sims, valid, count, stats = ivf_range_category(
        ivf, corpus, cats, q, radius, cfg=cfg)
    got_ids = np.asarray(ids)[np.asarray(valid)]
    got_sims = np.asarray(sims)[np.asarray(valid)]
    # ground truth per category
    hit, raw = flat.range_mask(q, radius)
    hit = np.asarray(hit)
    raw = np.asarray(raw)
    catnp = np.asarray(cats)
    for c in range(C):
        gt_rows = np.flatnonzero(hit & (catnp == c))
        gt_top = set(gt_rows[np.argsort(-raw[gt_rows])][:K].tolist())
        got_c = got_ids[catnp[got_ids] == c]
        top_got = set(got_c[np.argsort(-got_sims[catnp[got_ids] == c])][:K]
                      .tolist())
        # probe buffer must contain each category's true top-K
        assert gt_top.issubset(set(got_c.tolist())), f"category {c}"


def test_category_early_stop_beats_plain_range(corpus, ivf, flat):
    """Fig 9's point: with updateState the probe stops at R2 < R1."""
    q = _q(corpus, 7)
    C, K = 4, 2
    cats = jnp.asarray(
        np.random.default_rng(3).integers(0, C, corpus.shape[0]).astype(
            np.int32))
    radius = _radius_for(flat, q, 1500)     # huge R1
    cfg = ProbeConfig(max_probes=24, capacity=2048, num_categories=C,
                      k_per_category=K, no_new_category_stop=2)
    *_, stats_cat = ivf_range_category(ivf, corpus, cats, q, radius, cfg=cfg)
    *_, stats_rng = ivf_range(ivf, corpus, q, radius, cfg=cfg)
    assert int(stats_cat["probes"]) <= int(stats_rng["probes"])
    assert int(stats_cat["distance_evals"]) < corpus.shape[0]
