"""Dynamic batch scheduler (serving/scheduler.py): coalescing semantics,
effort-bucketed IVF correctness, and the virtual-clock simulation.

Contracts under test:
* coalesced requests produce exactly the results of a direct batched
  execution (per-request slicing is faithful);
* the deadline rule: a drain triggers on a full batch OR when the oldest
  request has waited ``max_wait_ms``, never before;
* ``run_effort_bucketed`` (pilot probe budget -> heavy-query re-run) is
  bit-identical to the lock-step bucketed run, light queries are final from
  phase 1, and the heavy set re-runs in a smaller bucket;
* the simulation serves every request with non-negative queueing delay and
  batch sizes within the configured bounds.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import EngineOptions, Metric, compile_query
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig
from repro.serving.scheduler import (BatchScheduler, SchedulerConfig,
                                     latency_stats, run_effort_bucketed)

SQL = ("SELECT sample_id FROM products WHERE price < ${p} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")


@pytest.fixture(scope="module")
def env():
    from repro.data import make_laion_catalog

    cat = make_laion_catalog(n_rows=1500, n_queries=8, dim=16, n_modes=8,
                             seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=32,
                    metric=Metric.INNER_PRODUCT, iters=3)
    cat.register_index("products", "embedding", idx)
    q = compile_query(SQL, cat, EngineOptions(
        engine="chase",
        probe=ProbeConfig(max_probes=32, probe_batch=2,
                          termination="counter")))
    return cat, q


def _requests(cat, n, seed=1):
    rng = np.random.default_rng(seed)
    base = np.asarray(cat.table("queries")["embedding"])
    price = np.asarray(cat.table("laion")["price"])
    reps = -(-n // base.shape[0])
    qs = np.tile(base, (reps, 1))[:n]
    qs = (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)
    # heterogeneous selectivity: permissive filters terminate after few
    # probes, selective ones keep probing -> a straggler-coupled batch
    ps = np.quantile(price, rng.uniform(0.05, 1.0, n)).astype(np.float32)
    return [dict(qv=jnp.asarray(qs[i]), p=jnp.float32(ps[i]))
            for i in range(n)]


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# coalescing
# ---------------------------------------------------------------------------

def test_coalesced_results_match_direct_batch(env):
    cat, q = env
    reqs = _requests(cat, 5)
    sched = BatchScheduler(q, SchedulerConfig(max_batch=8, max_wait_ms=0.0))
    rids = [sched.submit(**r) for r in reqs]
    done = sched.flush()
    assert sorted(done) == sorted(rids)
    direct = jax.tree.map(np.asarray, q.execute_bucketed(
        binds_list=[{k: np.asarray(v) for k, v in r.items()}
                    for r in reqs]))
    for i, rid in enumerate(rids):
        got = jax.tree.map(np.asarray, sched.result(rid))
        assert np.array_equal(got["ids"], direct["ids"][i])
        assert np.array_equal(got["stats"]["probes"],
                              direct["stats"]["probes"][i])


def test_deadline_semantics(env):
    cat, q = env
    clock = FakeClock()
    sched = BatchScheduler(q, SchedulerConfig(max_batch=3, max_wait_ms=5.0),
                           clock=clock)
    reqs = _requests(cat, 3)
    sched.submit(**reqs[0])
    assert not sched.due()                 # neither full nor expired
    assert sched.poll() == []
    clock.t = 0.004
    assert not sched.due()                 # 4ms < 5ms window
    clock.t = 0.0051
    assert sched.due()                     # oldest waited out its window
    done = sched.poll()
    assert len(done) == 1 and sched.pending() == 0
    # full batch drains immediately, regardless of the window
    clock.t = 1.0
    for r in reqs:
        sched.submit(**r)
    assert sched.due()
    assert len(sched.poll()) == 3


# ---------------------------------------------------------------------------
# effort bucketing
# ---------------------------------------------------------------------------

def test_effort_bucketed_is_bit_identical(env):
    cat, q = env
    reqs = _requests(cat, 12)
    binds = q._stack_binds([{k: np.asarray(v) for k, v in r.items()}
                            for r in reqs], {})
    lock = jax.tree.map(np.asarray, q.executor(binds))
    nat = np.asarray(lock["stats"]["probes"])
    pilot = int(np.percentile(nat, 60)) + 1   # most queries finish in phase 1
    eff, info = run_effort_bucketed(q, binds, pilot_budget=pilot)
    assert info["n_light"] + info["n_heavy"] == len(reqs)
    assert info["n_light"] > 0                # pilot actually splits the batch
    for key in ("ids", "sim", "valid"):
        assert np.array_equal(lock[key], np.asarray(eff[key])), key
    for sk in lock["stats"]:
        assert np.array_equal(lock["stats"][sk],
                              np.asarray(eff["stats"][sk])), sk


def test_effort_bucketed_through_scheduler(env):
    cat, q = env
    reqs = _requests(cat, 6)
    plain = BatchScheduler(q, SchedulerConfig(max_batch=8, max_wait_ms=0.0))
    effort = BatchScheduler(q, SchedulerConfig(max_batch=8, max_wait_ms=0.0,
                                               pilot_budget=8))
    outs = {}
    for sched in (plain, effort):
        rids = [sched.submit(**r) for r in reqs]
        sched.flush()
        outs[sched] = [jax.tree.map(np.asarray, sched.result(r))
                       for r in rids]
    for a, b in zip(outs[plain], outs[effort]):
        assert np.array_equal(a["ids"], b["ids"])
        assert np.array_equal(a["stats"]["probes"], b["stats"]["probes"])


def test_effort_bucketed_skips_non_native_plans(env):
    """The vmap fallback has no probe_budget lane: a pilot run would do
    full work and mark everything heavy — effort bucketing must fall back
    to single-phase instead of doubling the execution."""
    from repro.data import make_laion_catalog
    cat = make_laion_catalog(n_rows=800, n_queries=3, dim=16, n_modes=8,
                             seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=16,
                    metric=Metric.INNER_PRODUCT, iters=2)
    for name in ("laion", "images"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sql = """
    SELECT queries.id AS qid, images.sample_id AS tid
    FROM queries JOIN images
    ON DISTANCE(queries.embedding, images.embedding) <= ${r}
    """
    q = compile_query(sql, cat, EngineOptions(
        engine="chase", join_lowering="perleft", max_pairs=32,
        probe=ProbeConfig(max_probes=8)))
    assert not q.batch_native
    binds = q._stack_binds(None, {"r": jnp.asarray(np.float32([2.0, 2.5]))})
    lock = jax.tree.map(np.asarray, q.executor(binds))
    out, info = run_effort_bucketed(q, binds, pilot_budget=4)
    assert info["n_heavy"] == 0 and "skipped" in info
    assert np.array_equal(lock["tid"], np.asarray(out["tid"]))


def test_effort_bucketed_rejects_bad_pilot(env):
    cat, q = env
    binds = q._stack_binds([{k: np.asarray(v) for k, v in r.items()}
                            for r in _requests(cat, 2)], {})
    with pytest.raises(ValueError, match="pilot_budget"):
        run_effort_bucketed(q, binds, pilot_budget=0)


# ---------------------------------------------------------------------------
# simulation
# ---------------------------------------------------------------------------

def test_simulation_serves_all_with_sane_timelines(env):
    cat, q = env
    n = 16
    reqs = [{k: np.asarray(v) for k, v in r.items()}
            for r in _requests(cat, n)]
    sched = BatchScheduler(q, SchedulerConfig(max_batch=4, max_wait_ms=2.0))
    sched.warm(reqs[0], [1, 4])
    rng = np.random.default_rng(5)
    arrivals = np.sort(rng.exponential(0.002, n).cumsum())
    records = sched.simulate(arrivals, reqs)
    assert len(records) == n
    assert all(r.start >= r.arrival for r in records)       # no time travel
    assert all(r.finish > r.start for r in records)
    assert all(1 <= r.batch_size <= 4 for r in records)
    stats = latency_stats(records)
    assert stats["p50_ms"] <= stats["p95_ms"]


# ---------------------------------------------------------------------------
# deadlines, priorities, fault containment (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_flush_empty_and_submit_after_flush(env):
    cat, q = env
    sched = BatchScheduler(q, SchedulerConfig(max_batch=4, max_wait_ms=0.0))
    assert sched.flush() == []             # empty flush is a no-op
    assert sched.counters["batches"] == 0
    reqs = _requests(cat, 3)
    rids = [sched.submit(**r) for r in reqs]
    assert sorted(sched.flush()) == sorted(rids)
    # the scheduler is reusable after a flush: fresh rids, fresh results
    rid2 = sched.submit(**reqs[0])
    assert rid2 > max(rids)
    assert sched.flush() == [rid2]
    again = jax.tree.map(np.asarray, sched.result(rid2))
    direct = jax.tree.map(np.asarray, q.execute_bucketed(
        binds_list=[{k: np.asarray(v) for k, v in reqs[0].items()}]))
    assert np.array_equal(again["ids"], direct["ids"][0])


def test_all_expired_batch_never_executes(env):
    from repro.serving.resilience import DeadlineExceededError
    cat, q = env
    clock = FakeClock()
    sched = BatchScheduler(q, SchedulerConfig(max_batch=4, max_wait_ms=0.0),
                           clock=clock)
    reqs = _requests(cat, 3)
    rids = [sched.submit_request(dict(r), deadline_ms=5.0) for r in reqs]
    clock.t = 0.010                        # everyone is 5ms past deadline
    done = sched.flush()
    assert sorted(done) == sorted(rids)
    assert sched.counters["batches"] == 0  # nothing reached the executor
    assert sched.counters["shed_deadline"] == 3
    for rid in rids:
        with pytest.raises(DeadlineExceededError):
            sched.result(rid)


def test_deadline_tie_still_serves(env):
    """Shedding is strict (now > deadline): a drain at exactly the deadline
    serves the request instead of dropping it."""
    cat, q = env
    clock = FakeClock()
    sched = BatchScheduler(q, SchedulerConfig(max_batch=4, max_wait_ms=50.0),
                           clock=clock)
    (r0,) = _requests(cat, 1)
    rid = sched.submit_request(dict(r0), deadline_ms=10.0)
    clock.t = 0.004
    assert not sched.due()                 # before window AND deadline
    clock.t = 0.010                        # exactly the deadline
    assert sched.due()                     # tightest-deadline drain rule
    assert sched.poll() == [rid]
    out = sched.result(rid)                # served, not shed
    assert np.asarray(out["ids"]).shape == (4,)


def test_tightest_deadline_preempts_wait_window(env):
    cat, q = env
    clock = FakeClock()
    sched = BatchScheduler(
        q, SchedulerConfig(max_batch=8, max_wait_ms=100.0,
                           deadline_margin_ms=2.0), clock=clock)
    reqs = _requests(cat, 2)
    sched.submit_request(dict(reqs[0]))                     # no deadline
    sched.submit_request(dict(reqs[1]), deadline_ms=10.0)
    clock.t = 0.007
    assert not sched.due()                 # 10 - 2 margin = 8ms, not yet
    clock.t = 0.008
    assert sched.due()                     # batch must not idle past it
    assert len(sched.poll()) == 2


def test_priority_orders_drain(env):
    cat, q = env
    clock = FakeClock()
    sched = BatchScheduler(q, SchedulerConfig(max_batch=2, max_wait_ms=0.0),
                           clock=clock)
    reqs = _requests(cat, 3)
    r_low1 = sched.submit_request(dict(reqs[0]), priority=0)
    r_low2 = sched.submit_request(dict(reqs[1]), priority=0)
    r_high = sched.submit_request(dict(reqs[2]), priority=5)
    first = sched.poll()
    assert r_high in first and r_low1 in first   # prio, then arrival order
    assert sched.pending() == 1
    assert sched.flush() == [r_low2]


def test_execution_failure_is_contained_per_batch(env):
    cat, q = env

    class Flaky(BatchScheduler):
        fail_next = False

        def execute(self, binds_list):
            if self.fail_next:
                self.fail_next = False
                raise RuntimeError("injected batch failure")
            return super().execute(binds_list)

    sched = Flaky(q, SchedulerConfig(max_batch=4, max_wait_ms=0.0))
    reqs = _requests(cat, 4)
    bad = [sched.submit(**r) for r in reqs[:2]]
    sched.fail_next = True
    assert sorted(sched.flush()) == sorted(bad)
    for rid in bad:
        with pytest.raises(RuntimeError, match="injected batch failure"):
            sched.result(rid)
    assert sched.counters["failed"] == 2
    # the scheduler keeps serving after the contained failure
    good = [sched.submit(**r) for r in reqs[2:]]
    sched.flush()
    for rid in good:
        assert np.asarray(sched.result(rid)["ids"]).shape == (4,)
