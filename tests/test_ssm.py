"""Mamba2 SSD: chunked-scan forward vs a naive per-token recurrence oracle."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.ssm import ssm_decode, ssm_forward, ssm_init


def _naive_recurrence(p, cfg, u):
    """Token-at-a-time oracle using the decode step."""
    s = cfg.ssm
    bsz, S, d = u.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    conv = jnp.zeros((bsz, s.d_conv - 1, d_in), u.dtype)
    state = jnp.zeros((bsz, H, s.d_state, s.head_dim), jnp.float32)
    outs = []
    for t in range(S):
        y, conv, state = ssm_decode(p, cfg, u[:, t:t + 1], conv, state)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)


def test_ssd_chunked_equals_recurrent():
    cfg = get_config("mamba2-370m", smoke=True)
    p = ssm_init(jax.random.key(0), cfg)
    u = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunked = ssm_forward(p, cfg, u)       # chunk=16 => 2 chunks
    y_naive = _naive_recurrence(p, cfg, u)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-3)


def test_ssd_single_chunk_path():
    cfg = get_config("mamba2-370m", smoke=True)
    p = ssm_init(jax.random.key(2), cfg)
    u = jax.random.normal(jax.random.key(3), (1, 8, cfg.d_model),
                          jnp.float32) * 0.5
    y = ssm_forward(p, cfg, u)               # 8 < chunk => single chunk
    y_naive = _naive_recurrence(p, cfg, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-3)


def test_ssd_state_decay_causality():
    """Changing a future token must not affect past outputs (causality)."""
    cfg = get_config("mamba2-370m", smoke=True)
    p = ssm_init(jax.random.key(4), cfg)
    u = jax.random.normal(jax.random.key(5), (1, 32, cfg.d_model),
                          jnp.float32)
    y1 = ssm_forward(p, cfg, u)
    u2 = u.at[:, 20].set(123.0)
    y2 = ssm_forward(p, cfg, u2)
    np.testing.assert_allclose(np.asarray(y1[:, :20]),
                               np.asarray(y2[:, :20]), rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(y1[:, 20:]), np.asarray(y2[:, 20:]))
