"""Training loop: loss decreases on structured data; schedule; clipping."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.training import (AdamWConfig, TrainState, adamw_init,
                            build_train_step, warmup_cosine)


def test_loss_decreases_on_bigram_data():
    cfg = get_config("qwen2-1.5b", smoke=True)
    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=3, total_steps=40,
                          weight_decay=0.0)
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=32,
                                  vocab_size=cfg.vocab_size))
    params = init_params(jax.random.key(0), cfg)
    state = TrainState.create(params, adamw_init(opt_cfg, params),
                              jax.random.key(0))
    step = jax.jit(build_train_step(cfg, opt_cfg))
    losses = []
    for i in range(40):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)


def test_warmup_cosine_schedule():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lr0 = float(warmup_cosine(cfg, jnp.asarray(0)))
    lr_peak = float(warmup_cosine(cfg, jnp.asarray(10)))
    lr_end = float(warmup_cosine(cfg, jnp.asarray(100)))
    assert lr0 < lr_peak
    assert abs(lr_peak - 1e-3) < 1e-9
    assert lr_end < 1e-5


def test_gradient_clipping_activates():
    cfg = get_config("qwen2-1.5b", smoke=True)
    opt_cfg = AdamWConfig(lr_peak=1e-3, clip_norm=1e-6, warmup_steps=1,
                          total_steps=5)
    data = SyntheticLM(DataConfig(global_batch=2, seq_len=16,
                                  vocab_size=cfg.vocab_size))
    params = init_params(jax.random.key(0), cfg)
    state = TrainState.create(params, adamw_init(opt_cfg, params),
                              jax.random.key(0))
    step = jax.jit(build_train_step(cfg, opt_cfg))
    s1, m = step(state, data.batch_at(0))
    # with a tiny clip norm, the applied update is tiny
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(s1.params)))
    assert delta < 1e-2
