"""End-to-end behaviour: SQL in -> correct hybrid answers out, across the
whole stack (parser -> analyzer -> rewriter -> physical -> XLA), plus the
compiled-vs-interpreted speedup the paper's §6 claims."""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import EngineOptions, Metric, compile_query
from repro.core.interpreter import run_interpreted
from repro.data import make_laion_catalog
from repro.index import FlatIndex, build_ivf
from repro.index.ivf import ProbeConfig


def test_full_stack_q1(laion_catalog, query_vec):
    t = laion_catalog.table("laion")
    thr = float(np.quantile(np.asarray(t["price"]), 0.7))
    sql = ("SELECT sample_id FROM products WHERE price < ${p} "
           "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")
    q = compile_query(sql, laion_catalog,
                      EngineOptions(engine="chase",
                                    probe=ProbeConfig(max_probes=32,
                                                      termination="bound")))
    out = q(qv=query_vec, p=thr)
    flat = FlatIndex(Metric.INNER_PRODUCT, t["vec"])
    gt, _, _ = flat.topk(jnp.asarray(query_vec), 10, t["price"] < thr)
    assert set(np.asarray(out["ids"]).tolist()) \
        == set(np.asarray(gt).tolist())


def test_compiled_beats_interpreted():
    """The paper's §6 claim, measured: the jit-compiled engine runs the same
    query orders of magnitude faster than the tuple-at-a-time interpreter."""
    cat = make_laion_catalog(n_rows=2000, n_queries=2, dim=32, n_modes=16,
                             seed=3)
    qv = np.asarray(cat.table("queries")["embedding"][0])
    sql = ("SELECT sample_id FROM products WHERE price < ${p} "
           "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")

    compiled = compile_query(sql, cat, EngineOptions(engine="brute"))
    compiled(qv=qv, p=50.0)                       # compile once
    t0 = time.perf_counter()
    for _ in range(5):
        out = compiled(qv=qv, p=50.0)
    jax.block_until_ready(out["ids"])
    t_compiled = (time.perf_counter() - t0) / 5

    t0 = time.perf_counter()
    rows, counters = run_interpreted(sql, cat, {"p": 50.0, "qv": qv})
    t_interp = time.perf_counter() - t0

    assert t_interp > 5 * t_compiled, (t_interp, t_compiled)
    comp_ids = np.asarray(out["ids"])[np.asarray(out["valid"])].tolist()
    assert [int(r["sample_id"]) for r in rows] == comp_ids
