"""MoE: capacity dispatch vs dense-dispatch oracle; drop semantics; aux."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.moe import moe_apply, moe_apply_dense, moe_init


def _setup(arch="moonshot-v1-16b-a3b", seed=0):
    cfg = get_config(arch, smoke=True)
    p = moe_init(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.3
    return cfg, p, x


def test_capacity_dispatch_matches_dense_oracle():
    cfg, p, x = _setup()
    out, aux = moe_apply(p, cfg, x, capacity_factor=8.0)  # no drops
    want = moe_apply_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_capacity_dispatch_grok_style_ff_mode():
    cfg, p, x = _setup("grok-1-314b", seed=3)
    out, aux = moe_apply(p, cfg, x, capacity_factor=8.0)
    want = moe_apply_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_tiny_capacity_drops_tokens_not_nan():
    cfg, p, x = _setup(seed=5)
    out, aux = moe_apply(p, cfg, x, capacity_factor=0.1)
    assert np.isfinite(np.asarray(out)).all()
    # dropped tokens diverge from the oracle, but shapes/dtypes hold
    assert out.shape == x.shape


def test_grads_flow_through_dispatch():
    cfg, p, x = _setup(seed=7)

    def loss(p):
        out, aux = moe_apply(p, cfg, x, capacity_factor=4.0)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    norms = [float(jnp.linalg.norm(v.astype(jnp.float32)))
             for v in jax.tree.leaves(g)]
    assert sum(norms) > 0
    assert all(np.isfinite(n) for n in norms)
