"""Crash-recovery chaos for the live corpus (DESIGN.md §12).

For every injected crash site (all 9 WAL / snapshot / compaction points in
:data:`repro.serving.faults.CRASH_SITES`) and 3 seeds, a scripted mutation
sequence is killed mid-flight, then :func:`repro.data.mutations.recover`
rebuilds the corpus from disk alone into a FRESH catalog.  Asserted:

* **bit-identical to the unfailed replay** — the recovered state tree
  equals, leaf for leaf, the state an uncrashed process had at the same
  LSN (the durable frontier; a torn WAL tail loses exactly the un-synced
  record, never a committed one);
* **bit-identical to a from-scratch index** — compacting the recovered
  corpus equals a fresh :func:`attach_live` on its logical corpus (same
  canonical layout, same pinned-seed IVF arrays), i.e. recovery never
  leaves behind state a rebuild would not produce.

The same harness runs from CI via ``python -m benchmarks.run --chaos``.
"""
import copy
import os

import numpy as np
import pytest

from repro.core.schema import (Catalog, Metric, Schema, Table, float_col,
                               int_col, vector_col)
from repro.data.mutations import attach_live, recover
from repro.serving.faults import (CRASH_SITES, FaultInjector, FaultSpec,
                                  InjectedCrashError)

import jax.numpy as jnp

DIM = 8
N0 = 48
DELTA_CAP = 16


def _mk_catalog(seed: int) -> tuple[Catalog, np.ndarray]:
    rng = np.random.default_rng(seed)
    vecs = rng.standard_normal((N0, DIM)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    price = rng.uniform(1, 10, size=N0).astype(np.float32)
    schema = Schema({"sample_id": int_col(jnp.int64),
                     "price": float_col(),
                     "vec": vector_col(DIM, Metric.L2)})
    cat = Catalog()
    cat.register("items", Table(schema, {
        "sample_id": jnp.arange(N0, dtype=jnp.int64),
        "price": jnp.asarray(price), "vec": jnp.asarray(vecs)}))
    return cat, vecs


def _ops(seed: int) -> list[tuple]:
    """The scripted mutation sequence; hits every crash site at its first
    occurrence (inserts -> wal.*, snapshot() -> snapshot.*, compact() ->
    compact.*)."""
    rng = np.random.default_rng(1000 + seed)

    def v(n):
        x = rng.standard_normal((n, DIM)).astype(np.float32)
        return x / np.linalg.norm(x, axis=1, keepdims=True)

    return [("insert", np.arange(100, 105), v(5),
             {"price": np.full(5, 2.0, np.float32)}),
            ("delete", [3, 102]),
            ("snapshot",),
            ("insert", np.arange(200, 203), v(3), None),
            ("compact",),
            ("insert", np.arange(300, 302), v(2), None),
            ("delete", [200, 10]),
            ("compact",),
            ("insert_batch",
             [(np.arange(400, 403), v(3),
               {"price": np.full(3, 4.0, np.float32)}),
              (np.arange(410, 412), v(2))])]


def _apply(live, op):
    if op[0] == "insert":
        live.insert(op[1], op[2], op[3])
    elif op[0] == "insert_batch":
        live.insert_batch(op[1])
    elif op[0] == "delete":
        live.delete(op[1])
    elif op[0] == "snapshot":
        live.snapshot()
    else:
        live.compact()


def _attach(cat, path, seed, faults=None, **kw):
    nlist = 8 if seed == 2 else None     # seed 2 exercises the IVF rebuild
    return attach_live(cat, "items", "vec", path, delta_cap=DELTA_CAP,
                       nlist=nlist, seed=0, iters=3, faults=faults, **kw)


def _tree_equal(a, b, path=""):
    assert a.keys() == b.keys(), (path, sorted(a), sorted(b))
    for k in a:
        if isinstance(a[k], dict):
            _tree_equal(a[k], b[k], f"{path}{k}.")
        else:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]),
                                          err_msg=f"leaf {path}{k}")


def _replay_states(seed: int, path: str) -> dict[int, dict]:
    """Unfailed replay: state tree after attach and after every op, keyed
    by the LSN it left the corpus at."""
    cat, _ = _mk_catalog(seed)
    live = _attach(cat, path, seed)
    states = {live.lsn: copy.deepcopy(live._state_tree())}
    for op in _ops(seed):
        if op[0] == "insert_batch":
            # a torn group commit recovers to an INTERMEDIATE LSN (the
            # durable prefix of the group), so record every per-group
            # state — group commit is semantically sequential inserts
            for group in op[1]:
                live.insert(group[0], group[1],
                            group[2] if len(group) > 2 else None)
                states[live.lsn] = copy.deepcopy(live._state_tree())
        else:
            _apply(live, op)
            states[live.lsn] = copy.deepcopy(live._state_tree())
    return states


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("site", CRASH_SITES)
def test_crash_recovers_bit_identical(tmp_path, seed, site):
    cat, _ = _mk_catalog(seed)
    faults = FaultInjector(FaultSpec(seed=seed, crash_site=site,
                                     crash_at=1))
    live = _attach(cat, os.fspath(tmp_path / "a"), seed, faults=faults)
    crashed = False
    try:
        for op in _ops(seed):
            _apply(live, op)
    except InjectedCrashError:
        crashed = True
    assert crashed, f"site {site} never fired"
    assert faults.counters["crashes"] == 1

    # the process is gone: recovery sees only the disk state
    cat2, _ = _mk_catalog(seed)
    rec = recover(cat2, "items", "vec", os.fspath(tmp_path / "a"))

    states = _replay_states(seed, os.fspath(tmp_path / "b"))
    assert rec.lsn in states, (site, rec.lsn, sorted(states))
    _tree_equal(rec._state_tree(), states[rec.lsn])


@pytest.mark.parametrize("seed", [0, 1])
def test_torn_tail_truncated_so_later_mutations_survive(tmp_path, seed):
    """Recovery must truncate a torn WAL tail ON DISK: an append after a
    torn-tail recovery starts a fresh record instead of merging with the
    partial bytes, so a second recovery replays it (nothing corrupt,
    nothing silently dropped)."""
    cat, _ = _mk_catalog(seed)
    faults = FaultInjector(FaultSpec(seed=seed, crash_site="wal.torn_append",
                                     crash_at=2))
    live = _attach(cat, os.fspath(tmp_path / "a"), seed, faults=faults)
    with pytest.raises(InjectedCrashError):
        for op in _ops(seed):
            _apply(live, op)

    cat2, _ = _mk_catalog(seed)
    rec = recover(cat2, "items", "vec", os.fspath(tmp_path / "a"))
    with open(rec.wal_path, "rb") as f:
        raw = f.read()
    assert raw.endswith(b"\n")           # the half-flushed tail is gone

    # mutate PAST the recovery — the review scenario: these appends landed
    # after the partial bytes before the fix, corrupting the log
    rng = np.random.default_rng(7)
    v = rng.standard_normal((2, DIM)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    rec.insert([900, 901], v, {"price": np.full(2, 1.5, np.float32)})
    rec.delete([900])

    cat3, _ = _mk_catalog(seed)
    rec2 = recover(cat3, "items", "vec", os.fspath(tmp_path / "a"))
    assert rec2.lsn == rec.lsn
    _tree_equal(rec2._state_tree(), rec._state_tree())


@pytest.mark.parametrize("seed", [0, 2])
def test_recovered_corpus_equals_from_scratch_index(tmp_path, seed):
    """Compact the recovered corpus: segments AND the rebuilt IVF must be
    bit-identical to a fresh attach on the same logical corpus."""
    site = "compact.post_log" if seed else "wal.post_append"
    cat, _ = _mk_catalog(seed)
    faults = FaultInjector(FaultSpec(seed=seed, crash_site=site,
                                     crash_at=2))
    live = _attach(cat, os.fspath(tmp_path / "a"), seed, faults=faults)
    with pytest.raises(InjectedCrashError):
        for op in _ops(seed):
            _apply(live, op)
    cat2, _ = _mk_catalog(seed)
    rec = recover(cat2, "items", "vec", os.fspath(tmp_path / "a"))
    rec.compact()

    # fresh attach on the recovered logical corpus (survivors, canonical)
    m = np.flatnonzero(rec.main_valid)
    schema = Schema({"sample_id": int_col(jnp.int64),
                     "price": float_col(),
                     "vec": vector_col(DIM, Metric.L2)})
    cat3 = Catalog()
    cat3.register("items", Table(schema, {
        "sample_id": jnp.asarray(rec.cols["sample_id"][m]),
        "price": jnp.asarray(rec.cols["price"][m]),
        "vec": jnp.asarray(rec.main_vec[m])}))
    fresh = _attach(cat3, os.fspath(tmp_path / "c"), seed,
                    ids=rec.main_uids[m], cap_main=rec.cap_main)

    a, b = rec._state_tree(), fresh._state_tree()
    for skip in ("lsn", "compact_lsn"):  # clocks differ; layout must not
        a.pop(skip), b.pop(skip)
    _tree_equal(a, b)
    if seed == 2:                        # pinned-seed IVF arrays match too
        ia = cat2.index_for("items", "vec")
        ib = cat3.index_for("items", "vec")
        np.testing.assert_array_equal(np.asarray(ia.centroids),
                                      np.asarray(ib.centroids))
        np.testing.assert_array_equal(np.asarray(ia.lists),
                                      np.asarray(ib.lists))


# -- group commit (insert_batch): one fsync, sequential-insert semantics ----

def _groups(seed: int, base: int = 500):
    rng = np.random.default_rng(2000 + seed)

    def v(n):
        x = rng.standard_normal((n, DIM)).astype(np.float32)
        return x / np.linalg.norm(x, axis=1, keepdims=True)

    return [(np.arange(base, base + 3), v(3),
             {"price": np.full(3, 3.0, np.float32)}),
            (np.arange(base + 10, base + 12), v(2)),
            (np.arange(base + 20, base + 24), v(4), None)]


@pytest.mark.parametrize("seed", [0, 1])
def test_group_commit_equals_sequential_inserts(tmp_path, seed):
    """insert_batch is semantically sequential inserts (same LSNs, same
    segment layout) — it only collapses N fsyncs into one."""
    cat_a, _ = _mk_catalog(seed)
    a = _attach(cat_a, os.fspath(tmp_path / "a"), seed)
    lsns = a.insert_batch(_groups(seed))
    assert lsns == sorted(lsns) and len(lsns) == 3
    assert a.lsn == lsns[-1]

    cat_b, _ = _mk_catalog(seed)
    b = _attach(cat_b, os.fspath(tmp_path / "b"), seed)
    for g in _groups(seed):
        b.insert(g[0], g[1], g[2] if len(g) > 2 else None)
    _tree_equal(a._state_tree(), b._state_tree())


def test_group_commit_pays_one_fsync(tmp_path, monkeypatch):
    """The point of the group commit: N insert groups, ONE fsync."""
    import repro.data.mutations as mut
    cat, _ = _mk_catalog(0)
    live = _attach(cat, os.fspath(tmp_path / "a"), 0)
    counts = []
    real_fsync = os.fsync
    monkeypatch.setattr(mut.os, "fsync",
                        lambda fd: (counts.append(1), real_fsync(fd))[1])
    live.insert_batch(_groups(0))
    assert len(counts) == 1


def test_group_commit_rejection_has_no_side_effects(tmp_path):
    """A duplicate id ACROSS groups rejects the whole call before anything
    is logged or applied (all-or-nothing admission)."""
    from repro.serving.resilience import DeltaFullError, DuplicateIdError
    cat, _ = _mk_catalog(0)
    live = _attach(cat, os.fspath(tmp_path / "a"), 0)
    before = copy.deepcopy(live._state_tree())
    gs = _groups(0)
    dup = (np.asarray([500]), gs[0][1][:1])          # 500 already in group 0
    with pytest.raises(DuplicateIdError):
        live.insert_batch(gs + [dup])
    with pytest.raises(DeltaFullError):              # cumulative headroom
        live.insert_batch([_groups(0, base=600 + 10 * i)[2]
                           for i in range(5)])       # 20 rows > 16 cap
    _tree_equal(live._state_tree(), before)
    assert not os.path.exists(live.wal_path) or \
        b"600" not in open(live.wal_path, "rb").read()


@pytest.mark.parametrize("seed", [0, 1])
def test_group_commit_torn_tail_keeps_durable_prefix(tmp_path, seed):
    """A crash mid group commit (full prefix + half of the last line)
    recovers exactly the durable prefix groups, and the torn tail is
    truncated on disk so later appends start a fresh record."""
    cat, _ = _mk_catalog(seed)
    faults = FaultInjector(FaultSpec(seed=seed,
                                     crash_site="wal.group_commit",
                                     crash_at=1))
    live = _attach(cat, os.fspath(tmp_path / "a"), seed, faults=faults)
    with pytest.raises(InjectedCrashError):
        live.insert_batch(_groups(seed))

    cat2, _ = _mk_catalog(seed)
    rec = recover(cat2, "items", "vec", os.fspath(tmp_path / "a"))
    # 3 groups: the first 2 lines were complete, the 3rd was torn — the
    # recovered state must equal an unfailed twin that ran the first two
    # groups as sequential inserts (identical catalogs mint identical LSNs)
    cat_t, _ = _mk_catalog(seed)
    twin = _attach(cat_t, os.fspath(tmp_path / "t"), seed)
    for g in _groups(seed)[:2]:
        twin.insert(g[0], g[1], g[2] if len(g) > 2 else None)
    assert rec.lsn == twin.lsn
    _tree_equal(rec._state_tree(), twin._state_tree())
    live_uids = {int(u) for u in rec.delta_uids[np.flatnonzero(
        rec.delta_valid)]}
    assert {500, 501, 502, 510, 511} <= live_uids
    assert not any(520 <= u < 524 for u in live_uids)
    with open(rec.wal_path, "rb") as f:
        assert f.read().endswith(b"\n")  # torn tail shed on disk

    # appends after recovery start fresh records and replay cleanly
    rec.insert_batch(_groups(seed, base=700)[:2])
    cat3, _ = _mk_catalog(seed)
    rec2 = recover(cat3, "items", "vec", os.fspath(tmp_path / "a"))
    assert rec2.lsn == rec.lsn
    _tree_equal(rec2._state_tree(), rec._state_tree())
