"""Roofline machinery: trip-count-aware HLO analysis + shard-spec policy."""
import numpy as np
import pytest

from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_analyzer import analyze, parse_hlo
from repro.roofline.hw import TPU_V5E

HLO_WITH_LOOP = """
HloModule test

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %w = f32[256,256]{1,0} parameter(1)
  %dot.1 = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]{1,0}) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[128,256])) -> pred[] {
  %p2 = (s32[], f32[128,256]{1,0}) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[128,256]{1,0}) tuple(%z, %a)
  %w2 = (s32[], f32[128,256]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_analyzer_applies_trip_count():
    cost = analyze(HLO_WITH_LOOP)
    # dot flops: 2*128*256*256 per iter × 7 iters
    per_iter = 2 * 128 * 256 * 256
    assert cost.flops == pytest.approx(7 * per_iter)
    # all-reduce bytes: 128*256*4 per iter × 7
    assert cost.collective_bytes["all-reduce"] == pytest.approx(
        7 * 128 * 256 * 4)


def test_analyzer_parses_tuple_types_with_index_comments():
    # XLA inserts /*index=5*/ comments (containing '=') inside big tuples
    txt = HLO_WITH_LOOP.replace(
        "(s32[], f32[128,256]{1,0}) parameter(0)",
        "(s32[], /*index=1*/f32[128,256]{1,0}) parameter(0)")
    comps = parse_hlo(txt)
    assert "body" in comps and len(comps["body"].instrs) >= 5


def test_roofline_terms_math():
    t = roofline_terms({"flops": 1e12, "bytes accessed": 1e11},
                       {"all-reduce": 5e9}, chips=256, model_flops=2e14)
    assert t.compute_s == pytest.approx(1e12 / TPU_V5E.peak_flops_bf16)
    assert t.memory_s == pytest.approx(1e11 / TPU_V5E.hbm_bw)
    assert t.collective_s == pytest.approx(5e9 / TPU_V5E.ici_link_bw)
    assert t.dominant == "memory"   # 0.122s > 0.1s collective > compute
    assert t.useful_flops_fraction == pytest.approx(2e14 / (1e12 * 256))


def test_shardspec_divisibility_guard():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.launch.shardspec import safe_named_sharding
    # only runs meaningfully with 1 device: mesh (1,1) — axis size 1 => any
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = safe_named_sharding(mesh, {"heads": "model"}, ("heads", None),
                             (48, 128))
    assert sh.spec == P("model", None) or sh.spec == P(None, None)


class _FakeMesh:
    """Duck-typed 16x16 production mesh (rules_for only reads names/shape)."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_rules_for_policies():
    from repro.configs import get_config, get_shape
    from repro.launch.shardspec import moe_rules_patch, rules_for

    mesh = _FakeMesh()

    # long-context decode with batch=1: batch unsharded, kv_seq over DP
    cfg = get_config("gemma3-12b")
    r = rules_for(cfg, get_shape("long_500k"), mesh)
    assert r["batch"] is None
    assert r["kv_seq"] is not None

    # grok: 8 experts don't divide the model axis -> per-expert ff TP
    grok = get_config("grok-1-314b")
    r = moe_rules_patch(grok, rules_for(grok, get_shape("train_4k"), mesh))
    assert r["moe_ff"] == "model"
    # training FSDP on (>=10B)
    assert r["embed"] == "data"

    # moonshot: 64 experts shard over model
    moon = get_config("moonshot-v1-16b-a3b")
    r = moe_rules_patch(moon, rules_for(moon, get_shape("train_4k"), mesh))
    assert r["experts"] == "model"

    # FSDP stays on for >=10B at inference too (§Perf HC3 refuted TP-only:
    # replicated weights grow the per-token read term)
    g2 = get_config("gemma2-27b")
    r = rules_for(g2, get_shape("decode_32k"), mesh)
    assert r["embed"] == "data"
    r = rules_for(grok, get_shape("decode_32k"), mesh)
    assert r["embed"] == "data"
    # small archs never FSDP
    q = get_config("qwen2-1.5b")
    r = rules_for(q, get_shape("decode_32k"), mesh)
    assert r["embed"] is None

    # danube: kv=8 and hd=120 both fail 16-divisibility -> kv_seq on model
    dan = get_config("h2o-danube-3-4b")
    r = rules_for(dan, get_shape("decode_32k"), mesh)
    assert r["kv_seq"] == "model"
