"""Session API (DESIGN.md §9): the one front door, the normalized plan
cache, unified hints, and structured results.

Contracts under test:
* plan cache NORMALIZATION: whitespace / parameter-rename / conjunct-order
  variants of one SQL hit the same cache entry and compile ZERO new
  executables (asserted via ``trace_counts``); options or static-bind
  changes miss;
* SHIM PARITY: ``Statement.execute`` is bit-identical to the legacy
  ``CompiledQuery.__call__`` / ``execute_batch`` / ``execute_bucketed``
  surfaces for every query class Q1-Q6;
* ``ExecutionHints`` validates eagerly (construction) and against the
  prepared plan (execute);
* ``explain()`` reports LIVE executor state — compiled buckets,
  trace_counts, plan-cache hit, chosen lowering;
* ``db.serve`` round-trips through the BatchScheduler on a Statement
  (including renamed parameters);
* the shared-mutable-default fixes: fresh ProbeConfig / SchedulerConfig
  per instance, frozen everywhere.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.api import (Database, ExecutionHints, Result, ResultBatch,
                       connect)
from repro.core import (EngineOptions, Metric, compile_query,
                        plan_fingerprint, parse_sql)
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig
from repro.serving.scheduler import BatchScheduler, SchedulerConfig

PROBE = ProbeConfig(max_probes=16, capacity=128, termination="bound",
                    probe_batch=2)

Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2 = ("SELECT sample_id FROM images "
      "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}")
Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year >= ${y}
) AS ranked WHERE ranked.rank <= 4
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 3
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""
ALL_SQL = {"q1": Q1, "q2": Q2, "q3": Q3, "q4": Q4, "q5": Q5, "q6": Q6}


@pytest.fixture(scope="module")
def env():
    from repro.data import make_laion_catalog

    cat = make_laion_catalog(n_rows=900, n_queries=4, dim=16, n_modes=8,
                             num_categories=4, seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=16,
                    metric=Metric.INNER_PRODUCT, iters=3)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -30, axis=1)[:, -30]))
    return cat, radius


def _db(cat) -> Database:
    return connect(cat, EngineOptions(engine="chase", probe=PROBE))


def _qvecs(cat, qn: int) -> np.ndarray:
    base = np.asarray(cat.table("queries")["embedding"])
    rng = np.random.default_rng(3)
    reps = -(-qn // base.shape[0])
    qs = np.tile(base, (reps, 1))[:qn]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _binds_for(case: str, cat, radius: float, qn: int) -> list[dict]:
    """Per-query bind dicts for each query class (heterogeneous values)."""
    rng = np.random.default_rng(7)
    price = np.asarray(cat.table("laion")["price"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    years = np.asarray(cat.table("movies")["release_year"])
    qs = _qvecs(cat, qn)
    out = []
    for i in range(qn):
        if case == "q1":
            out.append({"qv": qs[i],
                        "p": np.float32(np.quantile(
                            price, rng.uniform(0.3, 1.0)))})
        elif case == "q2":
            out.append({"qv": qs[i],
                        "r": np.float32(radius * rng.uniform(0.95, 1.0)),
                        "d": np.int32(np.quantile(
                            dates, rng.uniform(0.2, 0.8)))})
        elif case in ("q3", "q6"):
            out.append({"r": np.float32(radius * rng.uniform(0.95, 1.0))})
        elif case == "q4":
            out.append({"y": np.int32(np.quantile(
                years, rng.uniform(0.1, 0.6)))})
        elif case == "q5":
            out.append({"qv": qs[i],
                        "r": np.float32(radius * rng.uniform(0.95, 1.0))})
    return out


def _trees_equal(a, b):
    a = jax.tree.map(np.asarray, a)
    b = jax.tree.map(np.asarray, b)
    assert set(a.keys()) == set(b.keys())
    for k in a:
        la, lb = jax.tree.leaves(a[k]), jax.tree.leaves(b[k])
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# plan cache normalization
# ---------------------------------------------------------------------------

def test_cache_hit_whitespace_variant(env):
    cat, _ = env
    db = _db(cat)
    s1 = db.prepare(Q1)
    s2 = db.prepare("""SELECT   sample_id
        FROM products
        WHERE price < ${p}
        ORDER BY DISTANCE(embedding, ${qv})
        LIMIT 4""")
    assert s2.cache_hit and not s1.cache_hit
    assert s2.compiled is s1.compiled
    assert db.cache_info().hits == 1
    assert db.cache_info().entries == 1


def test_cache_hit_param_rename_no_retrace(env):
    cat, _ = env
    db = _db(cat)
    s1 = db.prepare(Q1)
    binds = _binds_for("q1", cat, 0.0, 3)
    r1 = s1.execute(binds)
    assert dict(s1.executor.trace_counts) == {4: 1}
    renamed_sql = ("SELECT sample_id FROM products WHERE price < ${cap} "
                   "ORDER BY DISTANCE(embedding, ${vec}) LIMIT 4")
    s2 = db.prepare(renamed_sql)
    assert s2.cache_hit and s2.compiled is s1.compiled
    r2 = s2.execute([{"vec": b["qv"], "cap": b["p"]} for b in binds])
    # zero new executables: the renamed variant reused bucket 4's executable
    assert dict(s1.executor.trace_counts) == {4: 1}
    _trees_equal(r1.data, r2.data)


def test_cache_hit_conjunct_order_variant(env):
    cat, radius = env
    db = _db(cat)
    s1 = db.prepare(Q2)
    swapped = ("SELECT sample_id FROM images WHERE capture_date > ${dd} "
               "AND DISTANCE(embedding, ${q}) <= ${rr}")
    s2 = db.prepare(swapped)
    assert s2.cache_hit and s2.compiled is s1.compiled
    binds = _binds_for("q2", cat, radius, 2)
    r1 = s1.execute(binds)
    r2 = s2.execute([{"q": b["qv"], "rr": b["r"], "dd": b["d"]}
                     for b in binds])
    _trees_equal(r1.data, r2.data)


def test_cache_miss_on_options_and_statics(env):
    cat, _ = env
    db = _db(cat)
    db.prepare(Q1)
    assert db.prepare(Q1, options=EngineOptions(
        engine="vbase", probe=PROBE)).cache_hit is False
    assert db.prepare(Q1, options=EngineOptions(
        engine="chase",
        probe=dataclasses.replace(PROBE, max_probes=8))).cache_hit is False
    # static binds are part of the key (canonical slot, rename-proof)
    ksql = ("SELECT sample_id FROM products WHERE price < ${p} "
            "ORDER BY DISTANCE(embedding, ${qv}) LIMIT ${K}")
    k4 = db.prepare(ksql, K=4)
    assert db.prepare(ksql, K=8).cache_hit is False
    renamed = ("SELECT sample_id FROM products WHERE price < ${p} "
               "ORDER BY DISTANCE(embedding, ${qv}) LIMIT ${topk}")
    k4v = db.prepare(renamed, topk=4)
    assert k4v.cache_hit and k4v.compiled is k4.compiled


def test_fingerprint_distinguishes_plans(env):
    fp1, params1 = plan_fingerprint(parse_sql(Q1))
    fp2, _ = plan_fingerprint(parse_sql(Q2))
    assert fp1 != fp2
    assert params1 == ("p", "qv")  # canonical traversal order
    # a REAL structural difference must not collapse
    fp_lt, _ = plan_fingerprint(parse_sql(
        "SELECT sample_id FROM products WHERE price < ${p} "
        "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4"))
    fp_gt, _ = plan_fingerprint(parse_sql(
        "SELECT sample_id FROM products WHERE price > ${p} "
        "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4"))
    assert fp_lt != fp_gt


def test_unknown_bind_name_is_loud(env):
    cat, _ = env
    db = _db(cat)
    s = db.prepare(Q1)
    with pytest.raises(ValueError, match="unknown bind parameter"):
        s.execute({"qv": np.zeros(16, np.float32), "price": 1.0})


# ---------------------------------------------------------------------------
# shim parity: Statement.execute == legacy CompiledQuery surfaces (Q1-Q6)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["q1", "q2", "q3", "q4", "q5", "q6"])
def test_statement_parity_every_class(env, case):
    cat, radius = env
    opts = EngineOptions(engine="chase", probe=PROBE)
    legacy = compile_query(ALL_SQL[case], cat, opts)
    stmt = connect(cat, opts).prepare(ALL_SQL[case])
    binds_list = _binds_for(case, cat, radius, 3)

    # single path == __call__
    single = stmt.execute(binds_list[0])
    assert isinstance(single, Result) and not isinstance(single, ResultBatch)
    _trees_equal(single.data, legacy(**binds_list[0]))

    # list -> bucketed path == execute_bucketed
    bucketed = stmt.execute(binds_list)
    assert isinstance(bucketed, ResultBatch) and len(bucketed) == 3
    _trees_equal(bucketed.data,
                 legacy.execute_bucketed(binds_list=binds_list))

    # exact_shape hint == execute_batch
    exact = stmt.execute(binds_list, hints=ExecutionHints(exact_shape=True))
    _trees_equal(exact.data, legacy.execute_batch(binds_list=binds_list))


def test_stacked_dict_routes_to_batch(env):
    cat, _ = env
    db = _db(cat)
    stmt = db.prepare(Q1)
    binds_list = _binds_for("q1", cat, 0.0, 5)
    stacked = {"qv": np.stack([b["qv"] for b in binds_list]),
               "p": np.asarray([b["p"] for b in binds_list])}
    out = stmt.execute(stacked)
    assert isinstance(out, ResultBatch) and len(out) == 5
    _trees_equal(out.data, stmt.execute(binds_list).data)
    # per-query slicing view
    q2 = out.query(2)
    np.testing.assert_array_equal(np.asarray(q2["ids"]),
                                  np.asarray(out["ids"])[2])


def test_effort_hint_bit_identical(env):
    cat, _ = env
    db = _db(cat)
    stmt = db.prepare(Q1)
    binds_list = _binds_for("q1", cat, 0.0, 6)
    lock = stmt.execute(binds_list)
    eff = stmt.execute(binds_list, hints=ExecutionHints(pilot_budget=2))
    _trees_equal(lock.data, eff.data)
    rep = eff.explain()
    assert rep.path == "effort" and rep.effort is not None
    assert rep.effort["n_light"] + rep.effort["n_heavy"] == 6


def test_probe_budget_hint_caps_probes(env):
    cat, _ = env
    db = _db(cat)
    stmt = db.prepare(Q1)
    binds_list = _binds_for("q1", cat, 0.0, 4)
    out = stmt.execute(binds_list, hints=ExecutionHints(probe_budget=2))
    assert int(np.asarray(out.counters["probes"]).max()) <= 2
    # per-query budgets must match the batch size
    with pytest.raises(ValueError, match="3 entries for a batch of 4"):
        stmt.execute(binds_list,
                     hints=ExecutionHints(probe_budget=(2, 2, 2)))


def test_join_lowering_hint_reroutes_through_cache(env):
    cat, radius = env
    # probe_batch=1: the regime where the batch-native join lowering is
    # bit-identical to the per-left loop (the PR-2 parity contract)
    db = connect(cat, EngineOptions(
        engine="chase", probe=dataclasses.replace(PROBE, probe_batch=1)))
    stmt = db.prepare(Q3)
    binds_list = _binds_for("q3", cat, radius, 2)
    native = stmt.execute(binds_list)
    perleft = stmt.execute(binds_list,
                           hints=ExecutionHints(join_lowering="perleft"))
    _trees_equal(native.data, perleft.data)
    assert native.explain().batch_native
    assert not perleft.explain().batch_native
    assert "perleft" in perleft.explain().batch_lowering
    # the derived plan is itself cached
    s2 = db.prepare(Q3, hints=ExecutionHints(join_lowering="perleft"))
    assert s2.cache_hit


def test_join_lowering_reroute_keeps_statics_and_options(env):
    cat, _ = env
    db = _db(cat)
    ksql = ("SELECT sample_id FROM products WHERE price < ${p} "
            "ORDER BY DISTANCE(embedding, ${qv}) LIMIT ${K}")
    custom = EngineOptions(
        engine="chase", probe=dataclasses.replace(PROBE, max_probes=8))
    stmt = db.prepare(ksql, options=custom, K=4)
    binds_list = _binds_for("q1", cat, 0.0, 2)
    base = stmt.execute(binds_list)
    # the re-route must carry K=4 and the custom options base (it used to
    # drop both and crash on the unresolvable static K)
    rerouted = stmt.execute(binds_list,
                            hints=ExecutionHints(join_lowering="perleft"))
    _trees_equal(base.data, rerouted.data)   # VKNN ignores join lowering
    assert np.asarray(rerouted["ids"]).shape[-1] == 4


# ---------------------------------------------------------------------------
# hints validation
# ---------------------------------------------------------------------------

def test_hints_validate_eagerly():
    with pytest.raises(ValueError, match="join_lowering"):
        ExecutionHints(join_lowering="sideways")
    with pytest.raises(ValueError, match="pilot_budget"):
        ExecutionHints(pilot_budget=-1)
    with pytest.raises(ValueError, match="probe_budget must be >= 1"):
        ExecutionHints(probe_budget=0)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ExecutionHints(exact_shape=True, pilot_budget=3)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ExecutionHints(exact_shape=True, probe_budget=3)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ExecutionHints(pilot_budget=2, probe_budget=3)
    with pytest.raises(TypeError, match="sequence of ints"):
        ExecutionHints(probe_budget=object())
    # array-likes normalize to a hashable tuple (hints stay frozen keys)
    h = ExecutionHints(probe_budget=np.asarray([2, 3]))
    assert h.probe_budget == (2, 3)
    with pytest.raises(dataclasses.FrozenInstanceError):
        h.pilot_budget = 1


def test_hints_validate_against_plan(env):
    cat, _ = env
    db = _db(cat)
    stmt = db.prepare(Q1)
    binds_list = _binds_for("q1", cat, 0.0, 2)
    # batch-only hints are loud errors on the single path
    with pytest.raises(ValueError, match="single"):
        stmt.execute(binds_list[0], hints=ExecutionHints(probe_budget=2))
    with pytest.raises(ValueError, match="single"):
        stmt.execute(binds_list[0], hints=ExecutionHints(pilot_budget=2))
    with pytest.raises(ValueError, match="single"):
        stmt.execute(binds_list[0], hints=ExecutionHints(exact_shape=True))
    # a probe budget on the vmap-fallback lowering cannot be honored
    perleft = db.prepare(Q3, hints=ExecutionHints(join_lowering="perleft"))
    with pytest.raises(ValueError, match="probe_budget cannot be honored"):
        perleft.execute(_binds_for("q3", cat, 0.9, 2),
                        hints=ExecutionHints(join_lowering="perleft",
                                             probe_budget=2))


# ---------------------------------------------------------------------------
# explain: live executor state
# ---------------------------------------------------------------------------

def test_explain_reports_live_state(env):
    cat, _ = env
    db = _db(cat)
    stmt = db.prepare(Q1)
    rep0 = stmt.explain()
    assert rep0.buckets == () and rep0.cache_hit is False
    res = stmt.execute(_binds_for("q1", cat, 0.0, 3))
    rep1 = res.explain()
    assert rep1.buckets == (4,) and rep1.trace_counts == {4: 1}
    assert rep1.path == "bucketed" and rep1.bucket == 4
    assert rep1.num_queries == 3
    stmt.execute(_binds_for("q1", cat, 0.0, 9))
    # the SAME handle sees the newly compiled bucket: reports are live
    rep2 = res.explain()
    assert rep2.buckets == (4, 16)
    assert rep2.trace_counts == {4: 1, 16: 1}
    text = rep2.render()
    assert "native" in text and "bucket" in text
    s2 = db.prepare(Q1)
    assert s2.explain().cache_hit is True


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def test_serve_roundtrip_with_renamed_params(env):
    cat, _ = env
    db = _db(cat)
    stmt = db.prepare("SELECT sample_id FROM products WHERE price < ${cap} "
                      "ORDER BY DISTANCE(embedding, ${vec}) LIMIT 4")
    binds_list = _binds_for("q1", cat, 0.0, 5)
    renamed = [{"vec": b["qv"], "cap": b["p"]} for b in binds_list]
    server = db.serve(stmt, max_batch=8, max_wait_ms=0.0)
    rids = [server.submit(**b) for b in renamed]
    done = server.flush()
    assert sorted(done) == sorted(rids)
    got = np.stack([np.asarray(server.result(r)["ids"]) for r in rids])
    direct = stmt.execute(renamed)
    np.testing.assert_array_equal(got, np.asarray(direct["ids"]))


def test_serve_rejects_statics_on_statement(env):
    cat, _ = env
    db = _db(cat)
    stmt = db.prepare(Q1)
    with pytest.raises(TypeError, match="already-prepared"):
        db.serve(stmt, K=8)


def test_serve_from_sql_string(env):
    cat, _ = env
    db = _db(cat)
    server = db.serve(Q1, max_batch=4, max_wait_ms=0.0)
    b = _binds_for("q1", cat, 0.0, 1)[0]
    rid = server.submit(**b)
    server.flush()
    out = server.result(rid)
    stmt = db.prepare(Q1)          # cache hit: same plan the server uses
    assert stmt.cache_hit
    np.testing.assert_array_equal(
        np.asarray(out["ids"]),
        np.asarray(stmt.execute([b])["ids"])[0])


# ---------------------------------------------------------------------------
# shared-mutable-default fixes
# ---------------------------------------------------------------------------

def test_engine_options_probe_not_shared():
    a, b = EngineOptions(), EngineOptions()
    assert a.probe == b.probe
    assert a.probe is not b.probe          # default_factory, not one instance
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.engine = "vbase"
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.probe.max_probes = 1


def test_scheduler_config_not_shared(env):
    cat, _ = env
    stmt = _db(cat).prepare(Q1)
    s1, s2 = BatchScheduler(stmt), BatchScheduler(stmt)
    assert s1.config == s2.config
    assert s1.config is not s2.config      # None-sentinel, fresh per instance
    with pytest.raises(dataclasses.FrozenInstanceError):
        s1.config.max_batch = 1


def test_database_one_shot_execute(env):
    cat, _ = env
    db = _db(cat)
    b = _binds_for("q1", cat, 0.0, 1)[0]
    r1 = db.execute(Q1, b)
    r2 = db.execute(Q1, b)                 # second shot hits the cache
    assert db.cache_info().hits >= 1
    _trees_equal(r1.data, r2.data)
