"""Catalog-version plan invalidation + plan-cache LRU (DESIGN.md §11).

The PR-4/PR-5 stale-plan bug: compiled plans close over catalog state
(Table objects in predicate builders, the build-time index-presence
branch), so re-registering a table or index under a cached plan silently
served results from the *old* data.  The fix under test:

* every ``Catalog`` registration bumps a monotonic version clock;
* ``CompiledQuery.ensure_fresh`` checks the snapshot at execute time —
  plain index replacement **re-binds in place with zero retraces** (index
  arrays ride the executor's arrays argument), while structural drift
  (table re-registered, index presence flipped) raises
  :class:`~repro.core.StalePlanError`;
* session-API ``Statement``s recover transparently (re-prepare through the
  cache); legacy ``CompiledQuery`` surfaces raise loudly;
* the plan cache is LRU-bounded: evicted entries are marked, and
  Statements still holding one re-prepare on next execute (releasing the
  dead executables), asserted via ``trace_counts``.
"""
import jax
import numpy as np
import pytest

from repro.api import ExecutionHints, connect
from repro.core import Metric, StalePlanError, compile_query
from repro.data import make_laion_catalog
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig

SQL = ("SELECT sample_id FROM products WHERE price < ${p} "
       "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")


def _env(seed=0, with_index=True):
    cat = make_laion_catalog(n_rows=600, n_queries=4, dim=16, n_modes=8,
                             seed=seed)
    idx_a = build_ivf(jax.random.key(0), cat.table("laion")["vec"],
                      nlist=16, metric=Metric.INNER_PRODUCT, iters=2)
    idx_b = build_ivf(jax.random.key(1), cat.table("laion")["vec"],
                      nlist=16, metric=Metric.INNER_PRODUCT, iters=3)
    if with_index:
        cat.register_index("products", "embedding", idx_a)
    db = connect(cat, engine="chase",
                 probe=ProbeConfig(max_probes=16, probe_batch=2,
                                   termination="counter"))
    qv = np.asarray(cat.table("queries")["embedding"])[0].astype(np.float32)
    binds = {"qv": qv, "p": np.float32(1e9)}
    return cat, db, binds, (idx_a, idx_b)


def test_catalog_version_clock_is_monotonic():
    cat, _db, _binds, (idx_a, idx_b) = _env()
    key = ("index", "products", "embedding")
    v0 = cat.version(key)
    assert v0 > 0                           # registration bumped it
    cat.register_index("products", "embedding", idx_b)
    v1 = cat.version(key)
    cat.register_index("products", "embedding", idx_a)
    v2 = cat.version(key)
    assert v0 < v1 < v2
    assert cat.version(("table", "nonexistent")) == 0


def test_index_replacement_rebinds_in_place_without_retrace():
    cat, db, binds, (idx_a, idx_b) = _env()
    stmt = db.prepare(SQL)
    before = np.asarray(stmt.execute(binds).ids)
    traces = dict(stmt.executor.trace_counts)
    # a background rebuild lands: same shapes, different clustering
    cat.register_index("products", "embedding", idx_b)
    after = np.asarray(stmt.execute(binds).ids)
    # the re-bound plan serves the NEW index... with ZERO new traces
    fresh = np.asarray(connect(cat, engine="chase",
                               probe=ProbeConfig(max_probes=16,
                                                 probe_batch=2,
                                                 termination="counter"))
                       .prepare(SQL).execute(binds).ids)
    np.testing.assert_array_equal(after, fresh)
    assert dict(stmt.executor.trace_counts) == traces
    assert stmt.compiled.rebinds == 1
    # idempotent: no version change, no re-bind
    stmt.execute(binds)
    assert stmt.compiled.rebinds == 1


def test_stale_hit_is_recompiled_through_the_cache():
    cat, db, binds, (idx_a, idx_b) = _env()
    db.prepare(SQL)
    cat.register_index("products", "embedding", idx_b)
    stmt = db.prepare(SQL)                  # hit path must version-check
    got = np.asarray(stmt.execute(binds).ids)
    fresh = np.asarray(connect(cat, engine="chase",
                               probe=ProbeConfig(max_probes=16,
                                                 probe_batch=2,
                                                 termination="counter"))
                       .prepare(SQL).execute(binds).ids)
    np.testing.assert_array_equal(got, fresh)


def test_table_reregistration_raises_stale_plan_on_legacy_surface():
    cat, db, binds, _ = _env()
    q = compile_query(SQL, cat, db.options)
    q(**binds)
    # table swap: builders closed over the OLD Table's predicate columns
    cat.register("products", cat.table("laion"))
    with pytest.raises(StalePlanError, match="products"):
        q(**binds)


def test_index_presence_flip_raises_stale_plan():
    cat, db, binds, (idx_a, _idx_b) = _env(with_index=False)
    q = compile_query(SQL, cat, db.options)
    q(**binds)                              # compiled on the flat path
    cat.register_index("products", "embedding", idx_a)
    with pytest.raises(StalePlanError):     # arrays set changed shape
        q(**binds)


def test_statement_recovers_transparently_from_structural_staleness():
    cat, db, binds, _ = _env()
    stmt = db.prepare(SQL)
    before = np.asarray(stmt.execute(binds).ids)
    misses0 = db.cache_info().misses
    cat.register("products", cat.table("laion"))
    after = stmt.execute(binds)             # re-prepares, does not raise
    assert db.cache_info().misses == misses0 + 1
    assert np.asarray(after.ids).shape == before.shape


# ---------------------------------------------------------------------------
# plan-cache LRU bound
# ---------------------------------------------------------------------------

def test_lru_bound_evicts_and_statements_reprepare():
    cat, db0, binds, _ = _env()
    db = connect(cat, engine="chase", max_cached_plans=2,
                 probe=ProbeConfig(max_probes=16, probe_batch=2,
                                   termination="counter"))
    sqls = [SQL.replace("LIMIT 4", f"LIMIT {k}") for k in (2, 4, 8)]
    stmts = [db.prepare(s) for s in sqls]
    info = db.cache_info()
    assert info.entries == 2 and info.evictions == 1
    assert info.max_entries == 2
    assert stmts[0]._entry.evicted          # oldest fell off
    old_entry = stmts[0]._entry
    out = stmts[0].execute([binds])         # transparent re-prepare
    assert np.asarray(out.ids).shape == (1, 2)
    assert stmts[0]._entry is not old_entry
    assert not stmts[0]._entry.evicted
    # the re-prepared executor is fresh: exactly one trace for this bucket
    assert dict(stmts[0].executor.trace_counts) == {1: 1}
    # ...and that re-prepare itself evicted the next-oldest entry
    assert db.cache_info().evictions == 2


def test_lru_hit_refreshes_recency():
    cat, _db0, binds, _ = _env()
    db = connect(cat, engine="chase", max_cached_plans=2,
                 probe=ProbeConfig(max_probes=16, probe_batch=2,
                                   termination="counter"))
    sqls = [SQL.replace("LIMIT 4", f"LIMIT {k}") for k in (2, 4, 8)]
    s0 = db.prepare(sqls[0])
    db.prepare(sqls[1])
    db.prepare(sqls[0])                     # touch: s0 becomes most-recent
    db.prepare(sqls[2])                     # evicts sqls[1], not sqls[0]
    assert not s0._entry.evicted
    assert db.prepare(sqls[0]).cache_hit


def test_unbounded_cache_never_evicts():
    cat, _db0, _binds, _ = _env()
    db = connect(cat, engine="chase", max_cached_plans=None,
                 probe=ProbeConfig(max_probes=16, probe_batch=2,
                                   termination="counter"))
    for k in (2, 3, 4, 5, 6):
        db.prepare(SQL.replace("LIMIT 4", f"LIMIT {k}"))
    info = db.cache_info()
    assert info.entries == 5 and info.evictions == 0
    assert info.max_entries is None


def test_connect_rejects_bad_bound():
    cat, _db0, _binds, _ = _env()
    with pytest.raises(ValueError, match="max_cached_plans"):
        connect(cat, max_cached_plans=0)
