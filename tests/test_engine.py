"""End-to-end engine tests: compile + execute all six templates on all engine
modes; CHASE must match ground truth; baselines reproduce their plan-level
behaviors (oversampling recall loss, redundant evals)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EngineOptions, Metric, compile_query
from repro.index import FlatIndex
from repro.index.ivf import ProbeConfig

PROBE = ProbeConfig(max_probes=32, capacity=2048, termination="bound")


def _flat(cat):
    t = cat.table("laion")
    return FlatIndex(Metric.INNER_PRODUCT, t["vec"]), t


def test_q1_chase_exact_under_bound(laion_catalog, query_vec):
    flat, t = _flat(laion_catalog)
    price_thr = float(np.quantile(np.asarray(t["price"]), 0.5))
    mask = t["price"] < price_thr
    gt_ids, _, _ = flat.topk(jnp.asarray(query_vec), 20, mask)
    q = compile_query(
        "SELECT sample_id FROM products WHERE price < ${p} "
        "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 20",
        laion_catalog, EngineOptions(engine="chase", probe=PROBE))
    out = q(qv=query_vec, p=price_thr)
    assert set(np.asarray(out["ids"]).tolist()) \
        == set(np.asarray(gt_ids).tolist())
    # similarity emitted by the scan is correct (map-operator contract)
    got = np.asarray(out["sim"])
    vecs = np.asarray(t["vec"])[np.asarray(out["ids"])]
    np.testing.assert_allclose(got, vecs @ np.asarray(query_vec), rtol=1e-4,
                               atol=1e-5)


def test_q1_engines_agree_on_results(laion_catalog, query_vec):
    outs = {}
    for engine in ("chase", "vbase", "brute"):
        q = compile_query(
            "SELECT sample_id FROM products "
            "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10",
            laion_catalog, EngineOptions(engine=engine, probe=PROBE))
        outs[engine] = set(np.asarray(q(qv=query_vec)["ids"]).tolist())
    assert outs["chase"] == outs["brute"]
    assert outs["vbase"] == outs["brute"]


def test_q1_vbase_redundant_evals(laion_catalog, query_vec):
    """Fig 1c: VBASE's sort recomputes similarities the scan already had."""
    def evals(engine):
        q = compile_query(
            "SELECT sample_id FROM products "
            "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 25",
            laion_catalog, EngineOptions(engine=engine, probe=PROBE))
        return int(q(qv=query_vec)["stats"]["distance_evals"])
    assert evals("vbase") == evals("chase") + 25


def test_q1_pase_recall_drops_at_low_selectivity(laion_catalog, query_vec):
    """Fig 1b/§7.3.1: fixed K' oversampling loses recall under selective
    filters while CHASE's adaptive termination holds it."""
    t = laion_catalog.table("laion")
    thr = float(np.quantile(np.asarray(t["price"]), 0.03))
    flat, _ = _flat(laion_catalog)
    gt_ids, _, gt_valid = flat.topk(jnp.asarray(query_vec), 20,
                                    t["price"] < thr)
    gt = set(np.asarray(gt_ids)[np.asarray(gt_valid)].tolist())

    def recall(engine):
        q = compile_query(
            "SELECT sample_id FROM products WHERE price < ${p} "
            "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 20",
            laion_catalog,
            EngineOptions(engine=engine, probe=PROBE, pase_oversample=5))
        out = q(qv=query_vec, p=thr)
        ids = np.asarray(out["ids"])[np.asarray(out["valid"])]
        return len(set(ids.tolist()) & gt) / max(len(gt), 1)

    assert recall("chase") >= 0.95
    assert recall("pase") < recall("chase")


def test_q2_range(laion_catalog, query_vec):
    flat, t = _flat(laion_catalog)
    raw = np.asarray(t["vec"]) @ np.asarray(query_vec)
    srt = np.sort(raw)[::-1]
    radius = float((srt[80] + srt[81]) / 2)
    date_thr = int(np.quantile(np.asarray(t["capture_date"]), 0.5))
    hit, _ = flat.range_mask(jnp.asarray(query_vec), radius,
                             t["capture_date"] > date_thr)
    gt = set(np.flatnonzero(np.asarray(hit)).tolist())
    q = compile_query(
        "SELECT sample_id FROM images "
        "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}",
        laion_catalog, EngineOptions(engine="chase", probe=PROBE))
    out = q(qv=query_vec, r=radius, d=date_thr)
    got = set(np.asarray(out["ids"])[np.asarray(out["valid"])].tolist())
    assert got == gt


def test_q4_knn_join_vs_brute(laion_catalog):
    sql = """
    SELECT qid, tid FROM (
     SELECT users.id AS qid, movies.sample_id AS tid,
     RANK() OVER (PARTITION BY users.id
       ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
     FROM users JOIN movies ON users.preferred_rating = movies.rating
    ) AS ranked WHERE ranked.rank <= 5
    """
    chase = compile_query(sql, laion_catalog,
                          EngineOptions(engine="chase", probe=PROBE))()
    brute = compile_query(sql, laion_catalog,
                          EngineOptions(engine="brute"))()
    cid = np.asarray(chase["tid"])
    bid = np.asarray(brute["tid"])
    match = sum(set(cid[i]) == set(bid[i]) for i in range(cid.shape[0]))
    assert match >= cid.shape[0] - 1   # allow one boundary tie


def test_q5_category_partition(laion_catalog, query_vec):
    sql = """
    SELECT qid, category FROM (
     SELECT sample_id AS qid, calorie_level AS category,
     RANK() OVER (PARTITION BY calorie_level
       ORDER BY DISTANCE(embedding, ${qv})) AS rank
     FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
    ) AS ranked WHERE ranked.rank <= 4
    """
    t = laion_catalog.table("laion")
    raw = np.asarray(t["vec"]) @ np.asarray(query_vec)
    srt = np.sort(raw)[::-1]
    radius = float((srt[300] + srt[301]) / 2)
    out = compile_query(sql, laion_catalog,
                        EngineOptions(engine="chase", probe=PROBE))(
        qv=query_vec, r=radius)
    ids = np.asarray(out["ids"])
    valid = np.asarray(out["valid"])
    cats = np.asarray(t["calorie_level"])
    # per-category results actually belong to that category & are in range
    for c in range(ids.shape[0]):
        rows = ids[c][valid[c]]
        assert (cats[rows] == c).all()
        assert (raw[rows] >= radius - 1e-5).all()
    # vs ground truth per category
    for c in range(ids.shape[0]):
        in_range_rows = np.flatnonzero((raw >= radius) & (cats == c))
        want = set(in_range_rows[np.argsort(-raw[in_range_rows])][:4].tolist())
        got = set(ids[c][valid[c]].tolist())
        assert want == got, f"category {c}"


def test_explain_output(laion_catalog):
    q = compile_query(
        "SELECT sample_id FROM products ORDER BY "
        "DISTANCE(embedding, ${qv}) LIMIT 5",
        laion_catalog, EngineOptions(engine="chase", probe=PROBE))
    text = q.explain()
    assert "IndexScan" in text and "__sim" in text and "rewritten" in text
