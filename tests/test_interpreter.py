"""Volcano interpreter: same answers as the compiled engine, with the
per-tuple counters the paper's Table 5 analogue reads."""
import numpy as np
import pytest

from repro.core import EngineOptions, compile_query
from repro.core.interpreter import run_interpreted
from repro.data import make_laion_catalog


@pytest.fixture(scope="module")
def tiny_catalog():
    return make_laion_catalog(n_rows=400, n_queries=4, dim=16, n_modes=8,
                              num_categories=4, seed=7)


def test_q1_interpreter_matches_compiled(tiny_catalog):
    qv = np.asarray(tiny_catalog.table("queries")["embedding"][0])
    sql = ("SELECT sample_id FROM products WHERE price < ${p} "
           "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")
    rows, counters = run_interpreted(sql, tiny_catalog,
                                     {"p": 40.0, "qv": qv})
    interp_ids = [int(r["sample_id"]) for r in rows]
    out = compile_query(sql, tiny_catalog, EngineOptions(engine="brute"))(
        qv=qv, p=40.0)
    comp_ids = np.asarray(out["ids"])[np.asarray(out["valid"])].tolist()
    assert interp_ids == comp_ids          # identical ordering, exact engine
    assert counters.next_calls > len(rows)  # per-tuple overhead is real
    assert counters.distance_evals >= 400 * 0  # distances only on survivors


def test_q2_interpreter(tiny_catalog):
    qv = np.asarray(tiny_catalog.table("queries")["embedding"][1])
    t = tiny_catalog.table("laion")
    raw = np.asarray(t["vec"]) @ qv
    srt = np.sort(raw)
    radius = float((srt[-20] + srt[-21]) / 2)   # between keys: no tie flake
    sql = ("SELECT sample_id FROM images "
           "WHERE DISTANCE(embedding, ${qv}) <= ${r}")
    rows, counters = run_interpreted(sql, tiny_catalog,
                                     {"qv": qv, "r": radius})
    got = {int(r["sample_id"]) for r in rows}
    want = set(np.flatnonzero(raw >= radius).tolist())
    assert got == want
    assert counters.distance_evals == 400   # brute: one eval per tuple


def test_q4_interpreter_window(tiny_catalog):
    qv_tab = tiny_catalog.table("queries")
    sql = """
    SELECT qid, tid FROM (
     SELECT users.id AS qid, movies.sample_id AS tid,
     RANK() OVER (PARTITION BY users.id
       ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
     FROM users JOIN movies ON users.preferred_rating = movies.rating
    ) AS ranked WHERE ranked.rank <= 3
    """
    rows, counters = run_interpreted(sql, tiny_catalog, {})
    assert rows
    by_q = {}
    for r in rows:
        by_q.setdefault(int(r["qid"]), []).append(int(r["tid"]))
    for q, tids in by_q.items():
        assert len(tids) <= 3
    assert counters.tuples_materialized > 0
