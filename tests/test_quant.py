"""Quantized corpus scans (DESIGN.md §13): bit-parity with the fp32 path.

The EXACTNESS INVARIANT under test — ``EngineOptions.quant`` ('int8' /
'bf16') changes how many bytes the flat scan moves, never what it returns:

* **Q1-Q6 parity**: every query class, on both exact engines (brute and
  chase — IVF probes stay fp32, flat scans quantize), is BIT-identical to
  the fp32 path across batch sizes, the bucketed (pad-query) path, the
  exact-shape path, and the single-query front (which runs the batch
  lowering at Q=1 — so its reference is the fp32 *batched* execution);
* **adversarial corpora**: exact duplicates quantize identically and keep
  the fp32 lowest-id tie-break; near-tie rows whose differences vanish
  under quantization (sub-resolution for BOTH int8 and bf16) are ordered
  by the fused fp32 rescore, not by the quantized keys;
* **composition parity**: the sharded lowering at shards=1 and the
  live-delta lowering (insert / delete / compact — the main segment scans
  its quantized twin, the delta stays fp32) stay bit-identical to fp32;
* **zero-retrace rebind**: a re-registered twin and every live mutation
  re-bind through ``ensure_fresh`` without compiling anything
  (``trace_counts`` asserted);
* ``ExecutionHints.rescore_factor`` is compile-affecting (its own plan
  cache entry) and a wider replay changes nothing on an exact result;
* ``quantize_corpus`` honors the per-row contract (scale, half_step,
  all-zero rows, dequantized norms) and bad option combinations fail
  loud at compile time (``_validate_quant``).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecutionHints, connect
from repro.core import EngineOptions, Metric, compile_query
from repro.core.schema import Table
from repro.data import make_laion_catalog
from repro.data.mutations import attach_live
from repro.data.quantized import quantize_corpus
from repro.dist import DistSpec
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig

PROBE = ProbeConfig(max_probes=16, capacity=128, termination="bound",
                    probe_batch=2)
SPEC1 = DistSpec(mesh_shape=(1,), axes=("data",))
MODES = ("int8", "bf16")

Q1 = ("SELECT sample_id FROM products WHERE price < ${p} "
      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 4")
Q2 = ("SELECT sample_id FROM images "
      "WHERE DISTANCE(embedding, ${qv}) <= ${r} AND capture_date > ${d}")
Q3 = """
SELECT queries.id AS qid, images.sample_id AS tid
FROM queries JOIN images
ON DISTANCE(queries.embedding, images.embedding) <= ${r}
AND images.capture_date > queries.capture_date
"""
Q4 = """
SELECT qid, tid FROM (
 SELECT users.id AS qid, movies.sample_id AS tid,
 RANK() OVER (PARTITION BY users.id
   ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
 FROM users JOIN movies ON users.preferred_rating = movies.rating
 AND movies.release_year >= ${y}
) AS ranked WHERE ranked.rank <= 4
"""
Q5 = """
SELECT qid, category FROM (
 SELECT sample_id AS qid, calorie_level AS category,
 RANK() OVER (PARTITION BY calorie_level
   ORDER BY DISTANCE(embedding, ${qv})) AS rank
 FROM recipes WHERE DISTANCE(embedding, ${qv}) <= ${r}
) AS ranked WHERE ranked.rank <= 3
"""
Q6 = """
SELECT qid, category, tid FROM (
 SELECT queries.id AS qid, recipes.sample_id AS tid,
 recipes.calorie_level AS category,
 RANK() OVER (PARTITION BY queries.id, recipes.calorie_level
   ORDER BY DISTANCE(queries.embedding, recipes.embedding)) AS rank
 FROM queries JOIN recipes
 ON DISTANCE(queries.embedding, recipes.embedding) <= ${r}
 AND queries.cuisine <> recipes.cuisine
) AS ranked WHERE ranked.rank <= 3
"""
ALL_SQL = {"q1": Q1, "q2": Q2, "q3": Q3, "q4": Q4, "q5": Q5, "q6": Q6}

DIM = 16


@pytest.fixture(scope="module")
def env():
    cat = make_laion_catalog(n_rows=900, n_queries=4, dim=DIM, n_modes=8,
                             num_categories=4, seed=0)
    idx = build_ivf(jax.random.key(0), cat.table("laion")["vec"], nlist=16,
                    metric=Metric.INNER_PRODUCT, iters=3)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register_index(name, "vec", idx)
        cat.register_index(name, "embedding", idx)
    sims = (np.asarray(cat.table("queries")["embedding"])
            @ np.asarray(cat.table("laion")["vec"]).T)
    radius = float(np.median(np.partition(sims, -30, axis=1)[:, -30]))
    return cat, radius


@pytest.fixture(scope="module")
def dbs(env):
    """One Database per (engine, quant mode), shared across tests so
    repeated prepares hit the plan cache instead of recompiling."""
    cat, _ = env
    cache = {}

    def get(engine, quant=None):
        key = (engine, quant)
        if key not in cache:
            cache[key] = connect(cat, EngineOptions(
                engine=engine, probe=PROBE, use_pallas=True, quant=quant))
        return cache[key]

    return get


def _qvecs(cat, qn):
    base = np.asarray(cat.table("queries")["embedding"])
    rng = np.random.default_rng(3)
    reps = -(-qn // base.shape[0])
    qs = np.tile(base, (reps, 1))[:qn]
    return (qs + 0.01 * rng.standard_normal(qs.shape)).astype(np.float32)


def _binds_for(case, cat, radius, qn):
    rng = np.random.default_rng(7)
    price = np.asarray(cat.table("laion")["price"])
    dates = np.asarray(cat.table("laion")["capture_date"])
    years = np.asarray(cat.table("movies")["release_year"])
    qs = _qvecs(cat, qn)
    out = []
    for i in range(qn):
        if case == "q1":
            out.append({"qv": qs[i],
                        "p": np.float32(np.quantile(
                            price, rng.uniform(0.3, 1.0)))})
        elif case == "q2":
            out.append({"qv": qs[i],
                        "r": np.float32(radius * rng.uniform(0.95, 1.0)),
                        "d": np.int32(np.quantile(
                            dates, rng.uniform(0.2, 0.8)))})
        elif case in ("q3", "q6"):
            out.append({"r": np.float32(radius * rng.uniform(0.95, 1.0))})
        elif case == "q4":
            out.append({"y": np.int32(np.quantile(
                years, rng.uniform(0.1, 0.6)))})
        elif case == "q5":
            out.append({"qv": qs[i],
                        "r": np.float32(radius * rng.uniform(0.95, 1.0))})
    return out


def _trees_equal(a, b, ctx=""):
    a = jax.tree.map(np.asarray, dict(a))
    b = jax.tree.map(np.asarray, dict(b))
    assert set(a.keys()) == set(b.keys()), ctx
    import jax.tree_util as jtu
    la = jtu.tree_leaves_with_path(a)
    lb = jtu.tree_leaves_with_path(b)
    for (pa, x), (_pb, y) in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{ctx} leaf {jtu.keystr(pa)}")


# ---------------------------------------------------------------------------
# Q1-Q6 bit-parity: both exact engines x both modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("engine", ["brute", "chase"])
@pytest.mark.parametrize("case", sorted(ALL_SQL))
def test_parity_every_class(env, dbs, case, engine, mode):
    cat, radius = env
    binds = _binds_for(case, cat, radius, 5)       # bucketed: pads 5 -> 8
    want = dbs(engine).prepare(ALL_SQL[case]).execute(binds)
    got = dbs(engine, mode).prepare(ALL_SQL[case]).execute(binds)
    _trees_equal(want.data, got.data, ctx=f"{case}/{engine}/{mode}")


@pytest.mark.parametrize("mode", MODES)
def test_batch_sizes_pad_queries_and_exact_shape(env, dbs, mode):
    """Parity across batch sizes (1, 3-padded-to-4, 8) on the bucketed AND
    exact-shape paths — the q-valid pad lane must stay inert under quant."""
    cat, radius = env
    exact = ExecutionHints(exact_shape=True)
    for case in ("q1", "q5"):
        for qn in (1, 3, 8):
            binds = _binds_for(case, cat, radius, qn)
            ctx = f"{case}/qn={qn}/{mode}"
            want = dbs("brute").prepare(ALL_SQL[case])
            got = dbs("brute", mode).prepare(ALL_SQL[case])
            _trees_equal(want.execute(binds).data,
                         got.execute(binds).data, ctx=ctx)
            _trees_equal(want.execute(binds, hints=exact).data,
                         got.execute(binds, hints=exact).data,
                         ctx=ctx + "/exact_shape")


@pytest.mark.parametrize("mode", MODES)
def test_single_query_front_matches_fp32_batch(env, dbs, mode):
    """The quant single-query front IS the batch lowering at Q=1
    (``_single_via_batch``), so its bitwise reference is the fp32 BATCHED
    execution of one bind, sliced — not the fp32 single-query matvec."""
    cat, radius = env
    binds = _binds_for("q1", cat, radius, 1)
    got = dbs("brute", mode).prepare(Q1).execute(binds[0])     # Result
    want = dbs("brute").prepare(Q1).execute(
        binds, hints=ExecutionHints(exact_shape=True))         # batch of 1
    sliced = jax.tree.map(lambda v: np.asarray(v)[0], dict(want.data))
    _trees_equal(sliced, got.data, ctx=f"single/{mode}")


# ---------------------------------------------------------------------------
# adversarial corpora: ties the quantized keys cannot see
# ---------------------------------------------------------------------------

def _adversarial_catalog():
    """512-row corpus whose interesting rows sit mid-corpus (segments 32+):

    * rows 256..263 — EIGHT exact duplicates of the unit query direction u
      (identical quantization, identical fp32 keys: the lowest-id
      tie-break must survive the rescore's candidate reordering);
    * rows 264..279 — sixteen near-ties ``0.9*u + eps_i*e1`` with eps_i
      strictly increasing but SUB-RESOLUTION for both int8 (per-row scale
      step ~6e-3) and bf16 (ulp ~1.4e-3): their quantized keys tie
      exactly, so only the fused fp32 rescore can order them;
    * everything else — 0.1-scale noise, clearly outside the top-k.
    """
    n = 512
    cat = make_laion_catalog(n_rows=n, n_queries=4, dim=DIM, n_modes=8,
                             num_categories=4, seed=0)
    raw = np.linspace(1.0, 0.2, DIM).astype(np.float32)
    u = raw / np.linalg.norm(raw)
    rng = np.random.default_rng(5)
    vecs = 0.1 * rng.standard_normal((n, DIM)).astype(np.float32)
    vecs /= np.maximum(np.linalg.norm(vecs, axis=1, keepdims=True), 1e-6)
    vecs *= 0.1
    vecs[256:264] = u
    eps = (1e-6 * np.arange(1, 17)).astype(np.float32)
    near = np.tile(0.9 * u, (16, 1))
    near[:, 1] += eps
    vecs[264:280] = near
    tab = cat.table("laion")
    cols = {name: tab[name] for name in tab.schema.names()}
    cols["vec"] = cols["embedding"] = jnp.asarray(vecs)
    fresh = Table(tab.schema, cols)
    for name in ("laion", "products", "images", "recipes", "movies"):
        cat.register(name, fresh)
    return cat, u


@pytest.mark.parametrize("mode", MODES)
def test_adversarial_ties_and_duplicates(mode):
    cat, u = _adversarial_catalog()
    ksql = ("SELECT sample_id FROM products WHERE price < ${p} "
            "ORDER BY DISTANCE(embedding, ${qv}) LIMIT ${K}")
    binds = [{"qv": u.astype(np.float32), "p": np.float32(1e9)}] * 2
    fdb = connect(cat, EngineOptions(engine="brute", use_pallas=True))
    qdb = connect(cat, EngineOptions(engine="brute", use_pallas=True,
                                     quant=mode))
    want = fdb.prepare(ksql, K=12).execute(binds)
    got = qdb.prepare(ksql, K=12).execute(binds)
    _trees_equal(want.data, got.data, ctx=f"adversarial/{mode}")
    ids = np.asarray(got.data["ids"])[0].tolist()
    # duplicates: exact-tie keys resolve to the lowest ids, in id order
    assert ids[:8] == list(range(256, 264)), ids
    # near-ties: strictly-increasing eps under INNER_PRODUCT means the
    # LAST rows win ranks 9..12 — an ordering only fp32 can see
    assert ids[8:] == [279, 278, 277, 276], ids


# ---------------------------------------------------------------------------
# composition: sharded shards=1, live-delta, re-registered twins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("case", ["q1", "q2"])
def test_sharded_shards1_parity(env, case, mode):
    """quant + dist at shards=1 == plain fp32 flat path, bitwise — the
    per-shard local rescore happens before the (identity) merge."""
    cat, radius = env
    ref = compile_query(ALL_SQL[case], cat,
                        EngineOptions(engine="brute", use_pallas=True))
    q = compile_query(ALL_SQL[case], cat,
                      EngineOptions(engine="brute", use_pallas=True,
                                    quant=mode, dist=SPEC1))
    binds = _binds_for(case, cat, radius, 3)
    stacked = {k: np.stack([np.asarray(b[k]) for b in binds])
               for k in binds[0]}
    _trees_equal(ref.execute_bucketed(**stacked),
                 q.execute_bucketed(**stacked), ctx=f"dist/{case}/{mode}")


@pytest.mark.parametrize("mode", MODES)
def test_live_delta_parity_and_zero_retrace(tmp_path, mode):
    """Live mutations under quant: the main segment scans its quantized
    twin, the delta stays fp32, and insert/delete/compact stay bitwise
    equal to an identically-mutated fp32 plan — with ZERO retraces."""

    def mk():
        return make_laion_catalog(n_rows=240, n_queries=4, dim=DIM,
                                  n_modes=8, num_categories=4, seed=0)

    kw = dict(delta_cap=16, cap_main=304)
    cat, ref_cat = mk(), mk()
    live = attach_live(cat, "products", "embedding",
                       os.fspath(tmp_path / "a"), **kw)
    ref_live = attach_live(ref_cat, "products", "embedding",
                           os.fspath(tmp_path / "b"), **kw)
    qdb = connect(cat, EngineOptions(engine="brute", use_pallas=True,
                                     quant=mode))
    fdb = connect(ref_cat, EngineOptions(engine="brute", use_pallas=True))
    qs = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    binds = [{"qv": qs[i], "p": np.float32(1e9)} for i in range(3)]
    stmt, ref = qdb.prepare(Q1), fdb.prepare(Q1)
    _trees_equal(ref.execute(binds).data, stmt.execute(binds).data,
                 ctx=f"live/pre/{mode}")
    traces = dict(stmt.executor.trace_counts)
    assert traces                                   # compiled exactly once

    rng = np.random.default_rng(2)
    v = rng.standard_normal((3, DIM)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    for lv in (live, ref_live):
        lv.insert([9000, 9001, 9002], v,
                  {"price": np.full(3, 1.0, np.float32)})
    _trees_equal(ref.execute(binds).data, stmt.execute(binds).data,
                 ctx=f"live/insert/{mode}")
    for lv in (live, ref_live):
        lv.delete([9001, 17])
    _trees_equal(ref.execute(binds).data, stmt.execute(binds).data,
                 ctx=f"live/delete/{mode}")
    for lv in (live, ref_live):
        lv.compact()                 # canonical swap re-quantizes the main
    _trees_equal(ref.execute(binds).data, stmt.execute(binds).data,
                 ctx=f"live/compact/{mode}")
    # every mutation re-bound in place: zero new executables
    assert dict(stmt.executor.trace_counts) == traces


def test_requantized_twin_rebinds_zero_retraces():
    cat = make_laion_catalog(n_rows=240, n_queries=4, dim=DIM, n_modes=8,
                             num_categories=4, seed=0)
    db = connect(cat, EngineOptions(engine="brute", use_pallas=True,
                                    quant="int8"))
    stmt = db.prepare(Q1)
    qs = np.asarray(cat.table("queries")["embedding"]).astype(np.float32)
    binds = [{"qv": qs[i], "p": np.float32(1e9)} for i in range(3)]
    before = stmt.execute(binds)
    traces = dict(stmt.executor.trace_counts)
    # re-register a same-shape twin: ensure_fresh re-binds, nothing retraces
    twin = quantize_corpus(
        np.asarray(cat.table("products")["embedding"]), "int8")
    cat.register_quantized("products", "embedding", twin)
    after = stmt.execute(binds)
    assert dict(stmt.executor.trace_counts) == traces
    _trees_equal(before.data, after.data, ctx="requantize")


def test_rescore_factor_hint_is_compile_affecting(env, dbs):
    cat, radius = env
    db = connect(cat, EngineOptions(engine="brute", use_pallas=True,
                                    quant="int8"))
    stmt = db.prepare(Q1)
    binds = _binds_for("q1", cat, radius, 3)
    base = stmt.execute(binds)
    assert db.cache_info().entries == 1
    wide = stmt.execute(binds, hints=ExecutionHints(rescore_factor=3))
    # a distinct options fingerprint -> its own cache entry; the original
    # statement keeps its compiled default
    assert db.cache_info().entries == 2
    assert stmt.compiled.options.rescore_factor != 3
    # a wider replay on an already-exact result changes nothing
    _trees_equal(base.data, wide.data, ctx="rescore_factor")
    with pytest.raises(ValueError, match="rescore_factor"):
        ExecutionHints(rescore_factor=0)


# ---------------------------------------------------------------------------
# quantize_corpus unit contract + option validation
# ---------------------------------------------------------------------------

def test_quantize_corpus_int8_contract():
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((32, DIM)).astype(np.float32)
    vecs[5] = 0.0                                    # all-zero row
    qc = quantize_corpus(vecs, "int8")
    assert qc.qvecs.dtype == jnp.int8
    assert qc.scales.shape == (32, 1)
    deq = np.asarray(qc.qvecs, np.float32) * np.asarray(qc.scales)
    half = np.asarray(qc.half_step)
    assert np.all(np.abs(vecs - deq) <= half[:, None] + 1e-7)
    # all-zero row: scale pinned to 1, zero error bound, zero norms
    assert float(np.asarray(qc.scales)[5, 0]) == 1.0
    assert float(half[5]) == 0.0
    np.testing.assert_allclose(np.asarray(qc.row_l1),
                               np.abs(deq).sum(axis=1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(qc.row_l2),
                               np.linalg.norm(deq, axis=1), rtol=1e-6)


def test_quantize_corpus_bf16_contract():
    rng = np.random.default_rng(1)
    vecs = rng.standard_normal((8, DIM)).astype(np.float32)
    qc = quantize_corpus(vecs, "bf16")
    assert qc.qvecs.dtype == jnp.bfloat16
    # scales are EXACT ones: 1.0 * x is a bitwise identity, so ONE kernel
    # serves both modes
    assert np.all(np.asarray(qc.scales) == 1.0)
    deq = np.asarray(qc.qvecs, np.float32)
    half = np.max(np.abs(vecs), axis=1) * 2.0 ** -8
    np.testing.assert_allclose(np.asarray(qc.half_step), half, rtol=1e-6)
    assert np.all(np.abs(vecs - deq) <= half[:, None] + 1e-7)


def test_quantize_corpus_validation():
    vecs = np.ones((4, DIM), np.float32)
    with pytest.raises(ValueError, match="mode"):
        quantize_corpus(vecs, "fp8")
    with pytest.raises(ValueError, match="expected"):
        quantize_corpus(vecs[0], "int8")
    # plan_arrays carries the ensure_fresh re-bind keys, prefix included
    qc = quantize_corpus(vecs, "int8")
    assert set(qc.plan_arrays("m_")) == {
        "m_qvecs", "m_qscales", "m_qhalf", "m_ql1", "m_ql2"}


def test_quant_option_validation(env):
    cat, _ = env
    with pytest.raises(ValueError, match="use_pallas"):
        compile_query(Q1, cat, EngineOptions(
            engine="brute", use_pallas=False, quant="int8"))
    with pytest.raises(ValueError, match="chase"):
        compile_query(Q1, cat, EngineOptions(
            engine="vbase", use_pallas=True, quant="int8", probe=PROBE))
    with pytest.raises(ValueError, match="one of"):
        compile_query(Q1, cat, EngineOptions(
            engine="brute", use_pallas=True, quant="fp8"))
    with pytest.raises(ValueError, match="join_lowering"):
        compile_query(Q1, cat, EngineOptions(
            engine="brute", use_pallas=True, quant="int8",
            join_lowering="perleft"))
    with pytest.raises(ValueError, match=">= 1"):
        compile_query(Q1, cat, EngineOptions(
            engine="brute", use_pallas=True, quant="int8",
            rescore_factor=0))
