"""Semantic analyzer: Q1-Q6 classify into the paper's hybrid families."""
import pytest

from repro.core import QueryClass, analyze, parse_sql
from repro.core.expr import Param

from test_sql import Q1, Q2, Q3, Q4, Q5, Q6


def test_q1_vknn_sf(laion_catalog):
    sql = Q1.replace("category = ${cat} AND price < 100",
                     "nsfw = 0 AND price < 100") \
             .replace("SELECT id", "SELECT sample_id")
    a = analyze(parse_sql(sql), laion_catalog)
    assert a.query_class == QueryClass.VKNN_SF
    assert a.table == "products"
    assert a.vector_column == "embedding"
    assert a.k == 50
    assert isinstance(a.query_expr, Param)
    assert a.structured_predicate is not None


def test_q2_dr_sf(laion_catalog):
    sql = """
    SELECT sample_id FROM images
    WHERE DISTANCE(embedding, ${q}) <= ${T} AND capture_date > 100
    """
    a = analyze(parse_sql(sql), laion_catalog)
    assert a.query_class == QueryClass.DR_SF
    assert a.radius is not None
    assert a.structured_predicate is not None


def test_q3_dist_join(laion_catalog):
    sql = """
    SELECT queries.id AS qid, images.sample_id AS tid
    FROM queries JOIN images
    ON DISTANCE(queries.embedding, images.embedding) <= ${T}
    AND images.capture_date > queries.capture_date
    """
    a = analyze(parse_sql(sql), laion_catalog)
    assert a.query_class == QueryClass.DIST_JOIN
    assert a.left_table == "queries"
    assert a.right_table == "images"
    assert a.join_predicate is not None


def test_q4_knn_join(laion_catalog):
    sql = Q4.replace("movies.id", "movies.sample_id")
    a = analyze(parse_sql(sql), laion_catalog)
    assert a.query_class == QueryClass.KNN_JOIN
    assert a.k == 50
    assert a.left_table == "users"
    assert a.right_table == "movies"


def test_q5_category_partition(laion_catalog):
    sql = Q5.replace("SELECT id AS qid", "SELECT sample_id AS qid") \
            .replace("cuisine <> 'Italian'", "cuisine <> 3")
    a = analyze(parse_sql(sql), laion_catalog)
    assert a.query_class == QueryClass.CATEGORY_PARTITION
    assert a.category_column.name == "calorie_level"
    assert a.k == 10
    assert a.radius is not None


def test_q6_category_join(laion_catalog):
    sql = Q6.replace("recipes.id", "recipes.sample_id")
    a = analyze(parse_sql(sql), laion_catalog)
    assert a.query_class == QueryClass.CATEGORY_JOIN
    assert a.category_column.name == "calorie_level"
    assert len(a.partition_keys) == 2


def test_non_hybrid_falls_through(laion_catalog):
    a = analyze(parse_sql("SELECT sample_id FROM products WHERE price < 10"),
                laion_catalog)
    assert a.query_class == QueryClass.NON_HYBRID


def test_window_without_pk_partition_not_knn_join(laion_catalog):
    """Partitioning by a non-primary-key must NOT match the entity-centric
    pattern (paper §4.2: pk partitioning is a semantic requirement)."""
    sql = """
    SELECT qid FROM (
     SELECT users.id AS qid,
     RANK() OVER (PARTITION BY users.cuisine
       ORDER BY DISTANCE(users.embedding, movies.embedding)) AS rank
     FROM users JOIN movies ON users.preferred_rating = movies.rating
    ) AS ranked WHERE ranked.rank <= 5
    """
    a = analyze(parse_sql(sql), laion_catalog)
    assert a.query_class == QueryClass.NON_HYBRID
