"""CHASE-backed serving retrieval (the paper's technique in the LM stack)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.serving.rag import HybridRetriever
from repro.index import FlatIndex
from repro.core.schema import Metric
from repro.index.ivf import ProbeConfig


def _docs(n=2000, d=32, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    fresh = rng.random(n).astype(np.float32)
    safety = rng.integers(0, 4, n).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(fresh), jnp.asarray(safety)


def test_retriever_respects_filters():
    docs, fresh, safety = _docs()
    r = HybridRetriever.build(docs, fresh, safety, k=5, nlist=16,
                              probe=ProbeConfig(max_probes=16,
                                                termination="bound"))
    q = docs[3] + 0.01
    ids, sims, valid = r.retrieve(np.asarray(q), min_freshness=0.5,
                                  safety_class=1)
    got = np.asarray(ids)[np.asarray(valid)]
    assert (np.asarray(fresh)[got] >= 0.5).all()
    assert (np.asarray(safety)[got] == 1).all()
    # exact vs brute under 'bound'
    flat = FlatIndex(Metric.INNER_PRODUCT, docs)
    mask = (fresh >= 0.5) & (safety == 1)
    gt_ids, _, gt_valid = flat.topk(q, 5, mask)
    assert set(got.tolist()) == set(
        np.asarray(gt_ids)[np.asarray(gt_valid)].tolist())


def test_retriever_batched():
    docs, fresh, safety = _docs(seed=1)
    r = HybridRetriever.build(docs, fresh, safety, k=3, nlist=16)
    qs = np.asarray(docs[:6]) + 0.01
    ids, sims, valid = r.retrieve_batch(qs, min_freshness=0.0,
                                        safety_class=0)
    assert ids.shape == (6, 3)
    assert np.isfinite(np.asarray(sims)).all()
