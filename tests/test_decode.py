"""Decode-path correctness: token-by-token cached decode must reproduce the
full-sequence forward logits for EVERY architecture family — this exercises
KV caches, SWA ring buffers, SSM recurrent states, and zamba's shared block."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import forward, init_params
from repro.serving.decode import generate, prefill

# a representative per family (full battery would be slow on 1 CPU core)
DECODE_ARCHS = ["qwen2-1.5b", "gemma3-12b", "gemma2-27b", "mamba2-370m",
                "zamba2-1.2b", "grok-1-314b", "musicgen-medium"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.key(0), cfg)
    b, s = 2, 24
    key = jax.random.key(1)
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size,
                                  dtype=jnp.int32)
        full_logits, _ = forward(params, cfg, tokens=toks)
        _, dec_logits = prefill(params, cfg, tokens=toks, max_seq=s)
    else:
        emb = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        full_logits, _ = forward(params, cfg, embeds=emb)
        _, dec_logits = prefill(params, cfg, embeds=emb, max_seq=s)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer_beyond_window():
    """Decode past the window with a ring cache == forward with SWA mask."""
    cfg = get_config("gemma3-12b", smoke=True)   # window 16
    params = init_params(jax.random.key(0), cfg)
    b, s = 1, 40                                  # 40 > 16 window
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    full_logits, _ = forward(params, cfg, tokens=toks)
    _, dec_logits = prefill(params, cfg, tokens=toks, max_seq=s)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_generate_shapes_and_determinism():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_params(jax.random.key(0), cfg)
    prompts = jax.random.randint(jax.random.key(3), (2, 8), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    out1 = generate(params, cfg, prompts, 6)
    out2 = generate(params, cfg, prompts, 6)
    assert out1.shape == (2, 6)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))  # greedy
