"""Batched execution path: query-tiled kernels, multi-cluster IVF probes,
and the engine's execute_batch — parity against the per-query paths.

Contracts under test:
* ``fused_scan_topk_batch`` / ``fused_range_scan_batch`` equal the per-query
  fused kernels (ids up to ties, keys to 1e-5) across metrics, ragged
  Q/N/D padding shapes, and every mask mode (none / shared / per-query).
* ``ivf_topk_batch`` / ``ivf_range_batch`` with probe_batch=1 are
  bit-identical to the sequential probes (same probe prefix, same counters);
  with probe_batch>1 each query probes a SUPERSET prefix, so its kth key can
  only improve.
* batch results are permutation-invariant in the query axis.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.expr import order_key
from repro.core.schema import Metric
from repro.index import FlatIndex, build_ivf
from repro.index.ivf import (ProbeConfig, ivf_range, ivf_range_batch,
                             ivf_topk, ivf_topk_batch)
from repro.kernels import ref
from repro.kernels.ops import (fused_range_scan, fused_range_scan_batch,
                               fused_scan_topk, fused_scan_topk_batch)

METRICS = [Metric.INNER_PRODUCT, Metric.L2, Metric.COSINE]
# ragged shapes: none of Q/N/D aligned to the 8/128 tile multiples
SHAPES = [(1000, 48, 10, 7), (513, 33, 5, 1), (777, 96, 20, 33)]


def _data(n, d, qn, seed=0):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    qs = jnp.asarray(rng.standard_normal((qn, d)).astype(np.float32))
    shared = jnp.asarray(rng.random(n) < 0.5)
    per_q = jnp.asarray(rng.random((qn, n)) < 0.3)
    return c, qs, shared, per_q


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n,d,k,qn", SHAPES)
def test_scan_topk_batch_matches_single(metric, n, d, k, qn):
    c, qs, shared, per_q = _data(n, d, qn)
    for mask in (None, shared, per_q):
        ids, sims, valid = fused_scan_topk_batch(c, qs, k, mask, metric,
                                                 block_q=16, block_n=256)
        assert ids.shape == (qn, k)
        for qi in range(qn):
            rm = mask if (mask is None or mask.ndim == 1) else mask[qi]
            sids, ssims, svalid = fused_scan_topk(c, qs[qi], k, rm, metric,
                                                  block_n=256)
            assert np.array_equal(np.asarray(valid[qi]), np.asarray(svalid))
            kb = np.asarray(order_key(metric, sims[qi]))[np.asarray(valid[qi])]
            ks = np.asarray(order_key(metric, ssims))[np.asarray(svalid)]
            np.testing.assert_allclose(kb, ks, rtol=1e-5, atol=1e-5)
            if rm is not None:   # ids must satisfy the (per-query) mask
                got = np.asarray(ids[qi])[np.asarray(valid[qi])]
                assert np.asarray(rm)[got].all()


@pytest.mark.parametrize("metric", METRICS)
def test_range_scan_batch_matches_single(metric):
    n, d, qn = 700, 40, 5
    c, qs, shared, per_q = _data(n, d, qn, seed=1)
    keys = np.stack([np.asarray(ref.keys_ref(c, qs[i], metric))
                     for i in range(qn)])
    srt = np.sort(keys, axis=1)
    # strictly between adjacent keys => no boundary-tie flakiness
    rk = (srt[:, 100] + srt[:, 101]) / 2.0
    radius = jnp.asarray(-rk if metric.is_similarity() else rk)
    for mask in (None, shared, per_q):
        hit, raw, cnt = fused_range_scan_batch(c, qs, radius, mask, metric,
                                               block_q=8, block_n=128)
        for qi in range(qn):
            rm = mask if (mask is None or mask.ndim == 1) else mask[qi]
            shit, sraw, scnt = fused_range_scan(c, qs[qi], radius[qi], rm,
                                                metric, block_n=128)
            assert np.array_equal(np.asarray(hit[qi]), np.asarray(shit))
            assert int(cnt[qi]) == int(scnt)
            np.testing.assert_allclose(
                np.asarray(raw[qi])[np.asarray(hit[qi])],
                np.asarray(sraw)[np.asarray(shit)], rtol=1e-5, atol=1e-5)


def test_scan_topk_batch_query_permutation_invariant():
    c, qs, _shared, per_q = _data(512, 24, 9, seed=2)
    k = 6
    ids, sims, valid = fused_scan_topk_batch(c, qs, k, per_q, Metric.L2,
                                             block_q=8, block_n=128)
    perm = np.random.default_rng(3).permutation(9)
    ids_p, sims_p, valid_p = fused_scan_topk_batch(
        c, qs[perm], k, per_q[perm], Metric.L2, block_q=8, block_n=128)
    assert np.array_equal(np.asarray(ids_p), np.asarray(ids)[perm])
    np.testing.assert_allclose(np.asarray(sims_p), np.asarray(sims)[perm],
                               rtol=1e-6)
    assert np.array_equal(np.asarray(valid_p), np.asarray(valid)[perm])


# ---------------------------------------------------------------------------
# IVF probe parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=METRICS, ids=lambda m: m.value)
def ivf_env(request):
    metric = request.param
    rng = np.random.default_rng(0)
    modes = rng.standard_normal((16, 24)).astype(np.float32)
    which = rng.integers(0, 16, size=3000)
    x = modes[which] + 0.3 * rng.standard_normal((3000, 24)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    corpus = jnp.asarray(x)
    idx = build_ivf(jax.random.key(0), corpus, nlist=24, metric=metric,
                    iters=5)
    qs = corpus[:6] + 0.01
    mask = jnp.asarray(rng.random(3000) < 0.5)
    return metric, corpus, idx, qs, mask


@pytest.mark.parametrize("termination", ["counter", "bound"])
def test_ivf_topk_batch_parity_probe_batch_1(ivf_env, termination):
    metric, corpus, idx, qs, mask = ivf_env
    cfg = ProbeConfig(max_probes=24, termination=termination)
    ids, sims, valid, stats = ivf_topk_batch(idx, corpus, qs, 10, mask, cfg)
    for qi in range(qs.shape[0]):
        sids, ssims, svalid, sstats = ivf_topk(idx, corpus, qs[qi], 10,
                                               mask, cfg)
        assert np.array_equal(np.asarray(ids[qi]), np.asarray(sids))
        np.testing.assert_allclose(np.asarray(sims[qi]), np.asarray(ssims),
                                   rtol=1e-5, atol=1e-5)
        assert int(stats["probes"][qi]) == int(sstats["probes"])
        assert int(stats["distance_evals"][qi]) == \
            int(sstats["distance_evals"])


@pytest.mark.parametrize("probe_batch", [2, 4, 8])
def test_ivf_topk_multi_cluster_rounds_only_improve(ivf_env, probe_batch):
    """B clusters per round probe a superset prefix: kth key must not regress,
    and the round count shrinks ~B-fold."""
    metric, corpus, idx, qs, mask = ivf_env
    cfg1 = ProbeConfig(max_probes=24)
    cfgB = ProbeConfig(max_probes=24, probe_batch=probe_batch)
    _, sims1, valid1, stats1 = ivf_topk_batch(idx, corpus, qs, 10, mask, cfg1)
    _, simsB, validB, statsB = ivf_topk_batch(idx, corpus, qs, 10, mask, cfgB)
    k1 = np.asarray(order_key(metric, sims1))
    kB = np.asarray(order_key(metric, simsB))
    kth1 = np.where(np.asarray(valid1)[:, -1], k1[:, -1], np.inf)
    kthB = np.where(np.asarray(validB)[:, -1], kB[:, -1], np.inf)
    assert (kthB <= kth1 + 1e-5).all()
    # probes are counted per cluster; batched rounds may probe more clusters
    # but never fewer than the sequential prefix
    assert (np.asarray(statsB["probes"]) >= np.asarray(stats1["probes"])).all()


def test_ivf_range_batch_parity(ivf_env):
    metric, corpus, idx, qs, mask = ivf_env
    flat = FlatIndex(metric, corpus)
    _, raw0 = flat.range_mask(qs[0], 1e9 if metric.is_similarity() else -1e9)
    keys0 = np.sort(np.asarray(order_key(metric, raw0)))
    rk = (keys0[60] + keys0[61]) / 2.0
    radius = -rk if metric.is_similarity() else rk
    cfg = ProbeConfig(max_probes=24, capacity=512, termination="bound")
    ids, sims, valid, count, stats = ivf_range_batch(idx, corpus, qs, radius,
                                                     mask, cfg)
    for qi in range(qs.shape[0]):
        sids, ssims, svalid, scount, sstats = ivf_range(idx, corpus, qs[qi],
                                                        radius, mask, cfg)
        assert np.array_equal(np.asarray(ids[qi]), np.asarray(sids))
        assert int(count[qi]) == int(scount)
        assert int(stats["probes"][qi]) == int(sstats["probes"])


def test_ivf_topk_batch_query_permutation_invariant(ivf_env):
    metric, corpus, idx, qs, mask = ivf_env
    cfg = ProbeConfig(max_probes=24, probe_batch=4)
    ids, sims, valid, stats = ivf_topk_batch(idx, corpus, qs, 10, mask, cfg)
    perm = np.random.default_rng(5).permutation(qs.shape[0])
    ids_p, sims_p, valid_p, stats_p = ivf_topk_batch(idx, corpus, qs[perm],
                                                     10, mask, cfg)
    assert np.array_equal(np.asarray(ids_p), np.asarray(ids)[perm])
    np.testing.assert_allclose(np.asarray(sims_p), np.asarray(sims)[perm],
                               rtol=1e-6)
    assert np.array_equal(np.asarray(stats_p["probes"]),
                          np.asarray(stats["probes"])[perm])


# ---------------------------------------------------------------------------
# engine execute_batch
# ---------------------------------------------------------------------------

def test_execute_batch_matches_per_query(laion_catalog):
    from repro.core import EngineOptions, compile_query
    qv = np.asarray(laion_catalog.table("queries")["embedding"][:5])
    price = np.asarray(laion_catalog.table("laion")["price"])
    thr = float(np.quantile(price, 0.5))
    sql = ("SELECT sample_id FROM products WHERE price < ${p} "
           "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")
    for engine in ("chase", "brute"):
        q = compile_query(sql, laion_catalog,
                          EngineOptions(engine=engine,
                                        use_pallas=(engine == "brute")))
        out = q.execute_batch(qv=qv, p=thr)
        assert out["ids"].shape == (5, 10)
        for i in range(5):
            single = q(qv=qv[i], p=thr)
            assert np.array_equal(np.asarray(out["ids"][i]),
                                  np.asarray(single["ids"]))


def test_execute_batch_binds_list_and_per_query_filters(laion_catalog):
    """Per-query structured-filter constants in one batch (the serving shape:
    same plan, different tenant/freshness thresholds per request)."""
    from repro.core import EngineOptions, compile_query
    qv = np.asarray(laion_catalog.table("queries")["embedding"][:4])
    price = np.asarray(laion_catalog.table("laion")["price"])
    thrs = [float(np.quantile(price, s)) for s in (0.3, 0.5, 0.7, 0.9)]
    sql = ("SELECT sample_id FROM products WHERE price < ${p} "
           "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 5")
    q = compile_query(sql, laion_catalog, EngineOptions(engine="chase"))
    out = q.execute_batch(binds_list=[{"qv": qv[i], "p": thrs[i]}
                                      for i in range(4)])
    for i in range(4):
        single = q(qv=qv[i], p=thrs[i])
        assert np.array_equal(np.asarray(out["ids"][i]),
                              np.asarray(single["ids"]))
        got = np.asarray(out["ids"][i])[np.asarray(out["valid"][i])]
        assert (price[got] < thrs[i]).all()
