"""Hybrid-query-augmented serving: the paper's technique in the LM stack.

A qwen2-style model serves batched requests; before decoding, each request
runs a CHASE VKNN-SF query (similarity + freshness + safety filters) over a
document corpus, and the retrieved doc tokens are prepended (RAG).

The retriever rides the session API end to end: one Database session, one
prepared Statement (plan-cached), batched retrieval through the
size-bucketed executor, and an async submit/poll server from ``db.serve``.

  PYTHONPATH=src python examples/hybrid_serving.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.serving.decode import generate
from repro.serving.rag import HybridRetriever


def main():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_params(jax.random.key(0), cfg)

    # document corpus with structured metadata
    rng = np.random.default_rng(0)
    n_docs = 5000
    docs = rng.standard_normal((n_docs, cfg.d_model)).astype(np.float32)
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)
    freshness = rng.random(n_docs).astype(np.float32)
    safety = rng.integers(0, 4, n_docs).astype(np.int32)
    retriever = HybridRetriever.build(
        jnp.asarray(docs), jnp.asarray(freshness), jnp.asarray(safety), k=4)
    print(f"retriever over {n_docs} docs (CHASE VKNN-SF, fused filters)")
    print(retriever.statement.explain())

    # batched requests
    batch, prompt_len = 4, 12
    prompts = jax.random.randint(jax.random.key(1), (batch, prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    # query embeddings from mean prompt embedding (stub encoder)
    qemb = jnp.mean(params["embed"][prompts].astype(jnp.float32), axis=1)
    qemb = qemb / (jnp.linalg.norm(qemb, axis=-1, keepdims=True) + 1e-6)

    t0 = time.perf_counter()
    ids, sims, valid = retriever.retrieve_batch(np.asarray(qemb),
                                                min_freshness=0.3,
                                                safety_class=0)
    print(f"\nretrieved (k=4 docs/request, freshness>=0.3, safety=0) "
          f"in {(time.perf_counter()-t0)*1e3:.1f} ms:")
    for b in range(batch):
        print(f"  request {b}: docs={np.asarray(ids)[b].tolist()} "
              f"sims={np.round(np.asarray(sims)[b], 3).tolist()}")
    # check filters held
    got = np.asarray(ids)[np.asarray(valid)]
    assert (freshness[got] >= 0.3).all() and (safety[got] == 0).all()

    # async serving front-end: db.serve wraps the BatchScheduler over the
    # SAME prepared statement (shared plan-cache entry + bucket executables)
    server = retriever.db.serve(retriever.statement, max_batch=8,
                                max_wait_ms=0.0)
    rids = [server.submit(query_embedding=q, min_freshness=0.3,
                          safety_class=0) for q in qemb]
    server.flush()
    sched_ids = np.stack([np.asarray(server.result(r)["ids"]) for r in rids])
    assert np.array_equal(sched_ids, np.asarray(ids))
    print("async submit/poll through db.serve matches direct batch  [ok]")

    doc_tokens = (np.asarray(ids) * 7919 % cfg.vocab_size).astype(np.int32)
    prefix = jnp.concatenate([jnp.asarray(doc_tokens), prompts], axis=1)
    t0 = time.perf_counter()
    out = generate(params, cfg, prefix, 16)
    out = jax.block_until_ready(out)
    print(f"\ngenerated 16 tokens/request in "
          f"{time.perf_counter()-t0:.1f}s (incl. compile)")
    print(np.asarray(out))


if __name__ == "__main__":
    main()
