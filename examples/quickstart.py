"""Quickstart: hybrid queries on structured + unstructured data with CHASE.

Builds a LAION-shaped catalog, an IVF index, then runs the paper's Q1
(VKNN-SF) through four engine modes and EXPLAINs the rewritten plan.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.core import EngineOptions, Metric, compile_query
from repro.data import make_laion_catalog, selectivity_threshold
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig


def main():
    print("== building catalog (20k rows, 128-d) ==")
    cat = make_laion_catalog(n_rows=20_000, n_queries=4, dim=128,
                             n_modes=64, seed=0)
    corpus = cat.table("laion")["vec"]
    idx = build_ivf(jax.random.key(0), corpus, nlist=64,
                    metric=Metric.INNER_PRODUCT)
    cat.register_index("products", "embedding", idx)

    sql = """
    SELECT sample_id FROM products
    WHERE price < ${max_price}
    ORDER BY DISTANCE(embedding, ${image_embedding})
    LIMIT 10
    """
    qv = np.asarray(cat.table("queries")["embedding"][0])
    price = selectivity_threshold(
        np.asarray(cat.table("laion")["price"]), 0.5)

    print("\n== CHASE rewritten plan ==")
    q = compile_query(sql, cat, EngineOptions(
        engine="chase", probe=ProbeConfig(max_probes=32)))
    print(q.explain())

    print("\n== engines ==")
    for engine in ("chase", "vbase", "pase", "brute"):
        q = compile_query(sql, cat, EngineOptions(
            engine=engine, probe=ProbeConfig(max_probes=32)))
        out = q(image_embedding=qv, max_price=price)   # compile
        t0 = time.perf_counter()
        for _ in range(10):
            out = q(image_embedding=qv, max_price=price)
        jax.block_until_ready(out["ids"])
        dt = (time.perf_counter() - t0) / 10 * 1e3
        ids = np.asarray(out["ids"])[np.asarray(out["valid"])]
        print(f"{engine:6s}: {dt:7.2f} ms  "
              f"evals={int(out['stats']['distance_evals']):6d}  "
              f"top3={ids[:3].tolist()}")


if __name__ == "__main__":
    main()
