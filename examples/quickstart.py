"""Quickstart: hybrid queries on structured + unstructured data with CHASE.

Builds a LAION-shaped catalog and an IVF index, opens a session with the
front-door API (``connect -> prepare -> execute``), runs the paper's Q1
(VKNN-SF) through four engine modes, shows the normalized plan cache
collapsing textual variants, and EXPLAINs the live executor state.

  PYTHONPATH=src python examples/quickstart.py            # 20k rows
  PYTHONPATH=src python examples/quickstart.py --smoke    # CI-scale shapes
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.api import ExecutionHints, connect
from repro.core import EngineOptions, Metric, compile_query
from repro.data import make_laion_catalog, selectivity_threshold
from repro.index import build_ivf
from repro.index.ivf import ProbeConfig

SQL = """
SELECT sample_id FROM products
WHERE price < ${max_price}
ORDER BY DISTANCE(embedding, ${image_embedding})
LIMIT 10
"""

# same query, different whitespace AND renamed parameters — the normalized
# plan cache must collapse this onto SQL's compiled plan
SQL_VARIANT = ("SELECT sample_id FROM products WHERE price < ${cap} "
               "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale shapes (small catalog, fast)")
    args = ap.parse_args()
    n_rows, nlist = (2_000, 16) if args.smoke else (20_000, 64)

    print(f"== building catalog ({n_rows} rows, 128-d) ==")
    cat = make_laion_catalog(n_rows=n_rows, n_queries=4, dim=128,
                             n_modes=64, seed=0)
    corpus = cat.table("laion")["vec"]
    idx = build_ivf(jax.random.key(0), corpus, nlist=nlist,
                    metric=Metric.INNER_PRODUCT)
    cat.register_index("products", "embedding", idx)

    qv = np.asarray(cat.table("queries")["embedding"][0])
    price = selectivity_threshold(
        np.asarray(cat.table("laion")["price"]), 0.5)
    probe = ProbeConfig(max_probes=32)

    print("\n== session API: connect -> prepare -> execute ==")
    db = connect(cat, EngineOptions(engine="chase", probe=probe))
    stmt = db.prepare(SQL)
    res = stmt.execute({"image_embedding": qv, "max_price": price})
    ids = np.asarray(res.ids)[np.asarray(res.valid)]
    print(f"single query -> Result, top3={ids[:3].tolist()}")

    # batched: a list of bind dicts rides the size-bucketed serving path
    batch = stmt.execute([
        {"image_embedding": qv + 0.01 * i, "max_price": price}
        for i in range(3)])
    print(f"batch of {len(batch)} -> ResultBatch, ids shape "
          f"{np.asarray(batch.ids).shape}")

    print("\n== normalized plan cache ==")
    variant = db.prepare(SQL_VARIANT)       # renamed params, same plan
    vres = variant.execute({"qv": qv, "cap": price})
    assert np.array_equal(np.asarray(vres.ids), np.asarray(res.ids))
    info = db.cache_info()
    print(f"variant prepare was a cache {'hit' if variant.cache_hit else 'miss'}"
          f" (hits={info.hits}, misses={info.misses}, entries={info.entries})"
          f" — zero new executables compiled")

    print("\n== explain (live executor state) ==")
    print(batch.explain())

    print("\n== engine modes ==")
    for engine in ("chase", "vbase", "pase", "brute"):
        edb = connect(cat, EngineOptions(engine=engine, probe=probe))
        q = edb.prepare(SQL)
        binds = {"image_embedding": qv, "max_price": price}
        out = q.execute(binds)            # compile
        t0 = time.perf_counter()
        for _ in range(10):
            out = q.execute(binds)
        jax.block_until_ready(out["ids"])
        dt = (time.perf_counter() - t0) / 10 * 1e3
        ids = np.asarray(out.ids)[np.asarray(out.valid)]
        print(f"{engine:6s}: {dt:7.2f} ms  "
              f"evals={int(out.counters['distance_evals']):6d}  "
              f"top3={ids[:3].tolist()}")

    print("\n== legacy shim (old -> new mapping) ==")
    # old: q = compile_query(sql, cat, options); out = q(**binds)
    # new: stmt = connect(cat, options).prepare(sql); res = stmt.execute(binds)
    # (compile_query compiles fresh per call — no plan cache — but results
    #  are bit-identical to Statement.execute)
    legacy = compile_query(SQL, cat, EngineOptions(engine="chase",
                                                   probe=probe))
    lout = legacy(image_embedding=qv, max_price=price)
    assert np.array_equal(np.asarray(lout["ids"]), np.asarray(res["ids"]))
    print("compile_query(...)(**binds) == Statement.execute(binds)  [ok]")


if __name__ == "__main__":
    main()
