"""End-to-end training driver: train a ~100M-param qwen2-style model for a
few hundred steps on the synthetic bigram corpus, with async checkpointing
and crash-resume.

  PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to a scaled-down config so it finishes on CPU; pass --d-model 768
--layers 12 for the true ~100M config on real hardware)
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import Checkpointer, latest_step, restore
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.training import (AdamWConfig, TrainState, TrainStepConfig,
                            adamw_init, build_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b", smoke=True),
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(4, args.d_model // 64), num_kv_heads=2,
        d_ff=args.d_model * 4, vocab_size=2048, q_chunk=64)
    n = cfg.num_params_estimate()
    print(f"model: {cfg.num_layers}L d={cfg.d_model} params≈{n/1e6:.1f}M")

    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=20,
                          total_steps=args.steps)
    data = SyntheticLM(DataConfig(global_batch=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size))
    step_fn = jax.jit(build_train_step(cfg, opt_cfg, TrainStepConfig()),
                      donate_argnums=(0,))

    params = init_params(jax.random.key(0), cfg)
    state = TrainState.create(params, adamw_init(opt_cfg, params),
                              jax.random.key(0))
    start = 0
    ckpt = Checkpointer(args.ckpt_dir, keep_last_k=2)
    last = latest_step(args.ckpt_dir)
    if last is not None and last < args.steps:
        state = restore(args.ckpt_dir, last, jax.eval_shape(lambda: state))
        start = last
        print(f"resumed from checkpoint step {last}")

    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, data.batch_at(step))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step={step:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save_async(step + 1, state)
    ckpt.wait()
    ckpt.save_async(args.steps, state)
    ckpt.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
