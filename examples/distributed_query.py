"""Distributed hybrid query: corpus sharded over an 8-device mesh,
per-shard fused scan-topk, hierarchical collective merge.

Part 1 drives the raw single-query collective (DESIGN.md §5); part 2 runs
the shard × tile composition through the session API (`EngineOptions.dist`,
DESIGN.md §10): every device scans its row shard for ALL queries in the
batch at once, and `explain()` reports the shard count and merge depth.

Run with fake devices (any machine):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_query.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.schema import Metric
from repro.dist.collectives import (distributed_range, distributed_topk,
                                    shard_corpus)
from repro.index import FlatIndex
from repro.launch.mesh import make_mesh


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    n, d = 65536, 256
    corpus = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.5)       # structured filter

    flat = FlatIndex(Metric.INNER_PRODUCT, corpus)
    gt_ids, gt_sims, _ = flat.topk(q, 10, mask)

    with mesh:
        sh_corpus, sh_ids = shard_corpus(mesh, corpus)
        sh_mask = jax.device_put(mask, sh_ids.sharding)
        topk = jax.jit(distributed_topk(mesh, Metric.INNER_PRODUCT, 10))
        ids, sims, valid = topk(sh_corpus, sh_ids, q, sh_mask)   # compile
        t0 = time.perf_counter()
        for _ in range(10):
            ids, sims, valid = topk(sh_corpus, sh_ids, q, sh_mask)
        jax.block_until_ready(ids)
        dt = (time.perf_counter() - t0) / 10 * 1e3

    match = set(np.asarray(ids).tolist()) == set(np.asarray(gt_ids).tolist())
    print(f"distributed filtered top-10 over {n} sharded rows: {dt:.2f} ms, "
          f"exact={match}")
    print("ids:", np.asarray(ids).tolist())
    wire = 10 * 8 * 8   # K * (id+sim bytes) * shards
    print(f"wire bytes for the merge ≈ {wire} B vs {n*d*4/1e6:.0f} MB corpus"
          f" — the reason hybrid search shards across pods (DESIGN.md §5)")


def main_batched():
    """Part 2: the shard x tile composition through the session API."""
    from repro.api import DistSpec, connect
    from repro.core import EngineOptions
    from repro.data import make_laion_catalog

    cat = make_laion_catalog(n_rows=16384, n_queries=8, dim=64, n_modes=32,
                             seed=0)
    db = connect(cat, EngineOptions(engine="brute", use_pallas=True,
                                    dist=DistSpec(mesh_shape=(4,))))
    stmt = db.prepare("SELECT sample_id FROM products "
                      "ORDER BY DISTANCE(embedding, ${qv}) LIMIT 10")
    qs = np.asarray(cat.table("queries")["embedding"])      # (8, 64)
    out = stmt.execute({"qv": qs})                           # bucketed batch
    jax.block_until_ready(out["ids"])                        # compile
    t0 = time.perf_counter()
    for _ in range(10):
        out = stmt.execute({"qv": qs})
        jax.block_until_ready(out["ids"])
    dt = (time.perf_counter() - t0) / 10 * 1e3
    rep = out.explain()
    print(f"\nsession-API sharded batch (Q=8, shards={rep.shards}, "
          f"merge_depth={rep.merge_depth}): {dt:.2f} ms "
          f"({np.asarray(out['stats']['distance_evals'])[0]} evals/query)")


if __name__ == "__main__":
    main()
    main_batched()
